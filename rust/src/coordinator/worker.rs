//! Device worker: owns one simulated [`StreamAccelerator`], drains the
//! shared queue into per-network micro-batches and forwards them
//! through compiled command streams.
//!
//! Reconfiguration is the whole point (§4.1): a batch carries a network
//! tag, the worker resolves it against the shared
//! [`ModelRepo`] (through a small per-worker LRU of model handles) and
//! forwards through [`HostDriver::forward_compiled`] /
//! [`forward_batch_compiled`]. Command streams are loaded under their
//! artifact id, so the device's command shadow turns consecutive
//! same-network batches into zero-command-traffic replays — only a
//! network *switch* pays the transfer (counted in
//! [`crate::accel::stream::EngineStats`]). The worker loop has
//! **network affinity**: it prefers the network its device served last
//! (see [`batcher::next_batch_preferring`]), maximizing those
//! same-artifact runs so the command shadow *and* the cross-batch
//! weight residency (`gemm::WeightPlan` + the device's keyed weight
//! shadow) keep paying off.
//!
//! Batches of one ride the classic single-image path (the `batch=1`
//! degenerate case); larger batches go through the weight-resident
//! batched driver so each weight super-block crosses the link once per
//! batch. A failing or panicking forward no longer takes the whole run
//! down: the device is re-created (its caches and FIFOs may be
//! mid-flight) and a failed *multi-request* batch is retried member by
//! member so only the truly poisoned requests are reported failed —
//! innocent requests that merely shared a batch still get answers, and
//! completed responses are always drained.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::accel::stream::{StreamAccelerator, Watermarks, RES_FIFO_DEPTH};
use crate::compiler::{cost, verify, LruCache, ModelRepo, ServableModel};
use crate::host::batch::forward_batch_compiled;
use crate::host::driver::HostDriver;
use crate::host::postprocess;
use crate::hw::clock::ClockDomain;
use crate::hw::usb::UsbLink;
use crate::net::tensor::TensorF32;
use crate::telemetry::{Hub, Verdict};

use super::batcher::{self, BatchPolicy};
use super::metrics::FailedRequest;
use super::scheduler::{QueuedRequest, Scheduler};
use super::InferenceResponse;

/// What a worker reports back to the coordinator.
pub(crate) enum WorkerEvent {
    /// One request finished.
    Done(InferenceResponse),
    /// One micro-batch finished (metrics only).
    Batch(BatchMetric),
    /// One request failed (forward error or panic).
    Failed(FailedRequest),
}

/// Per-batch accounting emitted by a worker.
#[derive(Clone, Debug)]
pub(crate) struct BatchMetric {
    pub worker: usize,
    pub size: usize,
    /// Modeled link seconds this batch added on this worker's device.
    pub link_seconds: f64,
    /// Modeled engine seconds this batch added.
    pub engine_seconds: f64,
    /// Host wall seconds inside the forward.
    pub service_seconds: f64,
    pub weight_loads: u64,
    pub weight_sweeps: u64,
    /// Weight super-blocks found still resident from a previous batch
    /// (zero-traffic reloads via the device's keyed weight shadow).
    pub weight_reuses: u64,
    /// Command-stream link loads / shadow replays this batch added.
    pub command_loads: u64,
    pub command_reuses: u64,
    /// Whether the model handle came from the per-worker LRU.
    pub model_cache_hit: bool,
    /// Network this batch served (per-network drift accounting).
    pub network: String,
    /// Forced drain-barrier stalls this batch added.
    pub drain_stalls: u64,
    /// Device-lifetime peak occupancies after this batch (watermarks
    /// fold by max in the collector, not by sum).
    pub resfifo_peak: u64,
    pub cmdfifo_peak: u64,
    pub data_peak_words: u64,
    pub weight_peak_words: u64,
    /// Whether the online conformance checker sampled this batch.
    pub conformance_checked: bool,
    /// Typed `FA-DRIFT-*` events the checker raised on this batch.
    pub drift_events: u64,
}

/// Everything a worker needs besides the device and the batch at hand.
struct WorkerCtx<'a> {
    worker: usize,
    repo: &'a ModelRepo,
    link: UsbLink,
    tx: &'a mpsc::Sender<WorkerEvent>,
    /// Telemetry hub: batch sequence numbers, per-layer stat families.
    /// One relaxed load per batch decides whether any tracing work runs.
    hub: &'a Hub,
    /// Per-worker LRU of resolved model handles (network name → model).
    models: LruCache<String, Arc<ServableModel>>,
    /// Online-conformance sampling period: check every Nth batch
    /// (0 = off — the per-batch cost is one integer compare).
    conformance_sample: u32,
    /// Batches this worker has formed (drives the sampling cadence).
    batch_count: u64,
}

impl WorkerCtx<'_> {
    /// Resolve a batch's network tag to a model handle, LRU-cached.
    /// Returns the handle and whether it was a cache hit. Admission goes
    /// through [`ModelRepo::serveable`] — the serve-time verification
    /// gate — so a worker never reconfigures an engine from an artifact
    /// whose seal is missing or stale; such batches fail typed, the
    /// worker keeps running.
    fn model(&mut self, network: Option<&str>) -> Result<(Arc<ServableModel>, bool)> {
        let name = self.repo.resolve(network)?;
        if let Some(model) = self.models.get(&name) {
            return Ok((model, true));
        }
        let model = self
            .repo
            .serveable(&name)
            .with_context(|| format!("model {name:?} refused admission"))?;
        self.models.insert(name, model.clone());
        Ok((model, false))
    }
}

/// Run one worker until the queue closes. Never panics outward; errors
/// surface as [`WorkerEvent::Failed`].
pub(crate) fn run_worker(
    worker: usize,
    repo: &ModelRepo,
    link: UsbLink,
    sched: &Scheduler,
    policy: &BatchPolicy,
    model_cache: usize,
    conformance_sample: u32,
    hub: &Hub,
    tx: &mpsc::Sender<WorkerEvent>,
) {
    let mut ctx = WorkerCtx {
        worker,
        repo,
        link,
        tx,
        hub,
        models: LruCache::new(model_cache.max(1)),
        conformance_sample,
        batch_count: 0,
    };
    let mut dev = StreamAccelerator::new(link);
    // Network affinity: keep draining the network this device served
    // last, so its command + weight shadows stay hot and consecutive
    // same-artifact batches skip both transfers; switch when no
    // same-network request is queued — or when the streak hits the
    // aging cap (`batcher::MAX_AFFINITY_STREAK`), so sustained
    // one-network traffic cannot starve queued other-network requests.
    let mut last_network: Option<String> = None;
    let mut streak = 0usize;
    while let Some(batch) = batcher::next_batch_preferring(sched, policy, last_network.as_deref(), streak)
    {
        let network = batch[0].request.network.clone();
        if network == last_network {
            streak += 1;
        } else {
            streak = 1;
            last_network = network;
        }
        if !run_batch(&mut dev, &mut ctx, &batch, streak) {
            return; // coordinator went away
        }
    }
}

/// Forward one micro-batch and report results. On failure the device is
/// re-created and a multi-request batch is retried member by member, so
/// only truly poisoned requests fail. Returns `false` when the response
/// channel is gone (coordinator dropped).
fn run_batch(dev: &mut StreamAccelerator, ctx: &mut WorkerCtx, batch: &[QueuedRequest], streak: usize) -> bool {
    let size = batch.len();
    // Tracing is one relaxed load plus a scan of (small) batch members;
    // with it off, the rest of this function takes zero extra
    // timestamps and the device records no layer tape.
    let tracing = ctx.hub.tracing() && batch.iter().any(|q| q.request.trace.is_some());
    let t_batch = tracing.then(Instant::now);
    let (model, model_cache_hit) = match ctx.model(batch[0].request.network.as_deref()) {
        Ok(found) => found,
        Err(err) => {
            // Admission normally filters unknown networks; failing the
            // batch keeps the run draining even if one slips through.
            return fail_batch(batch, ctx.worker, format!("{err:#}"), ctx.hub, ctx.tx).is_ok();
        }
    };
    ctx.batch_count += 1;
    // Online conformance: sample every Nth batch (off at 0). The check
    // itself is pure arithmetic over counters the device already keeps,
    // so the forward's computation — and its bits — are untouched.
    let conformance =
        ctx.conformance_sample != 0 && ctx.batch_count % ctx.conformance_sample as u64 == 0;
    if ctx.hub.flight_recording() {
        ctx.hub.flight_event(
            "batch",
            batch[0].request.id,
            &model.name,
            &format!("worker {} assembled batch of {size}", ctx.worker),
        );
    }
    let images: Vec<TensorF32> = batch.iter().map(|q| q.request.image.clone()).collect();
    let link_before = dev.usb.total_seconds();
    let engine_before = ClockDomain::ENGINE.secs(dev.stats.cycles);
    let loads_before = dev.stats.weight_loads;
    let sweeps_before = dev.stats.weight_sweeps;
    let wreuses_before = dev.stats.weight_reuses;
    let cmd_loads_before = dev.stats.command_loads;
    let cmd_reuses_before = dev.stats.command_reuses;
    let stalls_before = dev.stats.drain_stalls;
    let passes_before = dev.stats.passes;
    let cycles_before = dev.stats.cycles;
    if conformance {
        dev.begin_occupancy_window();
    }
    if tracing {
        dev.begin_layer_tape();
    }
    let t0 = Instant::now();
    let outcome =
        match catch_unwind(AssertUnwindSafe(|| forward_probs(dev, &model, &images))) {
            Ok(Ok(probs)) => Ok(probs),
            Ok(Err(err)) => Err(format!("{err:#}")),
            Err(panic) => Err(panic_message(panic.as_ref())),
        };
    let service_seconds = t0.elapsed().as_secs_f64();
    match outcome {
        Ok(all_probs) => {
            let layers = if tracing { dev.take_layer_deltas() } else { Vec::new() };
            // The forward span closes *after* the tape drain: the tape's
            // last delta extends to drain time, so layer sub-spans are
            // guaranteed to nest inside the forward span.
            let t_done = Instant::now();
            if !layers.is_empty() {
                ctx.hub.record_layers(&model.name, &layers);
            }
            let batch_seq = tracing.then(|| ctx.hub.next_batch_seq());
            let link_seconds = dev.usb.total_seconds() - link_before;
            let engine_seconds = ClockDomain::ENGINE.secs(dev.stats.cycles) - engine_before;
            let modeled_each = (link_seconds + engine_seconds) / size as f64;
            let drifts = if conformance {
                conformance_drifts(
                    &model,
                    size,
                    dev.stats.passes - passes_before,
                    dev.stats.cycles - cycles_before,
                    &dev.occupancy_window(),
                )
            } else {
                Vec::new()
            };
            for d in &drifts {
                ctx.hub.flight_event("drift", batch[0].request.id, &model.name, d);
            }
            for (q, probs) in batch.iter().zip(all_probs) {
                let t_pp = tracing.then(Instant::now);
                let argmax = postprocess::argmax(&probs).unwrap_or(0);
                if let Some(tr) = q.request.trace.as_ref().filter(|_| tracing) {
                    // Queue span reconstructed backwards from the
                    // measured wait: it ended when this batch assembled.
                    let end_us = tr.instant_us(t_batch.unwrap_or(t0));
                    let start_us = end_us.saturating_sub((q.queue_wait * 1e6) as u64);
                    tr.span_us("queue", start_us, end_us - start_us);
                    tr.span("forward", t0, t_done);
                    for l in &layers {
                        tr.span_us(format!("layer {}", l.name), tr.instant_us(l.start), l.dur_us);
                    }
                    // Drift events surface on the trace stream too: one
                    // instant marker per typed event at forward end.
                    for d in &drifts {
                        let code = d.split(':').next().unwrap_or(d);
                        tr.span_us(format!("drift {code}"), tr.instant_us(t_done), 0);
                    }
                    if let Some(t_pp) = t_pp {
                        tr.span("postprocess", t_pp, Instant::now());
                    }
                    tr.set_batch(ctx.worker, batch_seq.unwrap_or(0), size, streak);
                    tr.set_verdict(Verdict::Served);
                }
                let done = WorkerEvent::Done(InferenceResponse {
                    id: q.request.id,
                    network: model.name.clone(),
                    probs,
                    argmax,
                    worker: ctx.worker,
                    service_seconds,
                    modeled_seconds: modeled_each,
                    queue_wait_seconds: q.queue_wait,
                    batch_size: size,
                });
                if ctx.tx.send(done).is_err() {
                    return false;
                }
            }
            let wm = dev.watermarks();
            let metric = BatchMetric {
                worker: ctx.worker,
                size,
                link_seconds,
                engine_seconds,
                service_seconds,
                weight_loads: dev.stats.weight_loads - loads_before,
                weight_sweeps: dev.stats.weight_sweeps - sweeps_before,
                weight_reuses: dev.stats.weight_reuses - wreuses_before,
                command_loads: dev.stats.command_loads - cmd_loads_before,
                command_reuses: dev.stats.command_reuses - cmd_reuses_before,
                model_cache_hit,
                network: model.name.clone(),
                drain_stalls: dev.stats.drain_stalls - stalls_before,
                resfifo_peak: wm.resfifo,
                cmdfifo_peak: wm.cmdfifo,
                data_peak_words: wm.data_words,
                weight_peak_words: wm.weight_words,
                conformance_checked: conformance,
                drift_events: drifts.len() as u64,
            };
            ctx.tx.send(WorkerEvent::Batch(metric)).is_ok()
        }
        Err(error) => {
            if error.contains("panicked") {
                ctx.hub.flight_event("panic", batch[0].request.id, &model.name, &error);
                ctx.hub.flight_dump(&format!("worker {} panic: {error}", ctx.worker));
            }
            // The device may be mid-transfer: start from a clean one.
            *dev = StreamAccelerator::new(ctx.link);
            if size == 1 {
                fail_batch(batch, ctx.worker, error, ctx.hub, ctx.tx).is_ok()
            } else {
                // Don't let one poisoned request fail its batch-mates:
                // replay each member alone (recursion depth is 1).
                for q in batch {
                    if !run_batch(dev, ctx, std::slice::from_ref(q), streak) {
                        return false;
                    }
                }
                true
            }
        }
    }
}

/// Forward a batch through the compiled stream and return per-image
/// softmax probabilities.
fn forward_probs(
    dev: &mut StreamAccelerator,
    model: &ServableModel,
    images: &[TensorF32],
) -> Result<Vec<Vec<f32>>> {
    if images.len() == 1 {
        let r = HostDriver::new(dev).forward_compiled(&model.stream, &model.blobs, &images[0])?;
        Ok(vec![r.probs])
    } else {
        let b = forward_batch_compiled(dev, &model.stream, &model.blobs, images)?;
        Ok(b.items.into_iter().map(|i| i.probs).collect())
    }
}

/// Online oracle conformance: compare what the device actually did on
/// this batch against what the compile-time cost oracle promised and
/// what the static verifier bounded. Returns one human-readable string
/// per typed `FA-DRIFT-*` event (empty = conformant). Pure arithmetic
/// over counters the device already keeps — no extra device work.
fn conformance_drifts(
    model: &ServableModel,
    size: usize,
    measured_passes: u64,
    measured_cycles: u64,
    wm: &Watermarks,
) -> Vec<String> {
    let cs = &model.stream;
    let mut out = Vec::new();
    // 1. Stamp self-check: re-derive the modeled cost at the stamped
    //    batch/residency. A forged or stale `modeled` diverges here no
    //    matter what batch size the request traffic happens to use.
    let fresh = cost::stream_cost(cs, cs.modeled.batch.max(1), cs.modeled.residency);
    if fresh != cs.modeled {
        out.push(format!(
            "{}: stamped cost model diverges from a fresh re-derivation",
            verify::FA_DRIFT_COST
        ));
    }
    // 2. Measured vs modeled: passes and engine cycles are residency-
    //    invariant, so a Cold re-derivation at the live batch size is an
    //    exact prediction of both (link traffic is residency-dependent
    //    and deliberately excluded).
    let want = cost::stream_cost(cs, size, cost::Residency::Cold).total();
    if measured_passes != want.passes || measured_cycles != want.cycles {
        out.push(format!(
            "{}: measured passes/cycles {}/{} != modeled {}/{} (batch {})",
            verify::FA_DRIFT_COST, measured_passes, measured_cycles, want.passes, want.cycles, size
        ));
    }
    // 3. Occupancy: the single-image driver drains after every pass, so
    //    its RESFIFO watermark must respect the static verifier's
    //    per-stream bound. The batched driver legitimately lets results
    //    pool across images, so only the hardware depth binds there.
    let bound = if size == 1 {
        verify::resfifo_stream_bound(cs)
    } else {
        RES_FIFO_DEPTH as u64
    };
    if wm.resfifo > bound {
        out.push(format!(
            "{}: RESFIFO watermark {} exceeds the verified bound {}",
            verify::FA_DRIFT_OCCUPANCY, wm.resfifo, bound
        ));
    }
    out
}

fn fail_batch(
    batch: &[QueuedRequest],
    worker: usize,
    error: String,
    hub: &Hub,
    tx: &mpsc::Sender<WorkerEvent>,
) -> Result<(), mpsc::SendError<WorkerEvent>> {
    for q in batch {
        if let Some(tr) = &q.request.trace {
            tr.set_verdict(Verdict::Failed);
        }
        hub.flight_event("fail", q.request.id, q.request.network.as_deref().unwrap_or(""), &error);
        tx.send(WorkerEvent::Failed(FailedRequest {
            id: q.request.id,
            worker,
            error: error.clone(),
        }))?;
    }
    // Typed request failures are exactly the moments worth a post-mortem:
    // snapshot the ring so the events leading up to this failure survive.
    hub.flight_dump(&format!("request failure on worker {worker}: {error}"));
    Ok(())
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        format!("worker panicked: {s}")
    } else if let Some(s) = payload.downcast_ref::<String>() {
        format!("worker panicked: {s}")
    } else {
        "worker panicked".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::InferenceRequest;
    use crate::net::graph::Network;
    use crate::net::layer::LayerSpec;
    use crate::net::tensor::Tensor;
    use crate::net::weights::synthesize_weights;
    use crate::prop::Rng;

    fn tiny_net() -> Network {
        let mut n = Network::new("w");
        let inp = n.input(6, 3);
        let c1 = n.engine(LayerSpec::conv("c1", 3, 1, 0, 6, 3, 8, 0), inp);
        let gap = n.engine(LayerSpec::avgpool("gap", 4, 1, 4, 8), c1);
        n.softmax("prob", gap);
        n
    }

    fn tiny_repo() -> ModelRepo {
        let net = tiny_net();
        let blobs = synthesize_weights(&net, 3);
        let mut repo = ModelRepo::new();
        repo.register(net, blobs).unwrap();
        repo
    }

    fn good_request(id: u64, rng: &mut Rng) -> InferenceRequest {
        InferenceRequest::new(
            id,
            Tensor::from_vec(6, 6, 3, (0..6 * 6 * 3).map(|_| rng.normal(1.0)).collect()),
        )
    }

    #[test]
    fn worker_drains_queue_and_reports_metrics() {
        let repo = tiny_repo();
        let sched = Scheduler::new();
        let mut rng = Rng::new(1);
        sched.push_all((0..5).map(|id| good_request(id, &mut rng)));
        sched.close();
        let (tx, rx) = mpsc::channel();
        run_worker(
            0,
            &repo,
            crate::hw::usb::UsbLink::usb3_frontpanel(),
            &sched,
            &BatchPolicy::batched(4),
            4,
            0,
            &Hub::new(1),
            &tx,
        );
        drop(tx);
        let mut done = 0;
        let mut batches = Vec::new();
        let mut cmd_loads = 0u64;
        let mut cmd_reuses = 0u64;
        for ev in rx {
            match ev {
                WorkerEvent::Done(r) => {
                    assert_eq!(r.worker, 0);
                    assert_eq!(r.network, "w");
                    assert!(r.modeled_seconds > 0.0);
                    done += 1;
                }
                WorkerEvent::Batch(m) => {
                    batches.push(m.size);
                    cmd_loads += m.command_loads;
                    cmd_reuses += m.command_reuses;
                }
                WorkerEvent::Failed(f) => panic!("unexpected failure: {}", f.error),
            }
        }
        assert_eq!(done, 5);
        assert_eq!(batches.iter().sum::<usize>(), 5);
        assert!(batches.len() >= 2, "4+1 expected, got {batches:?}");
        // One network: commands crossed the link once, then replayed.
        assert_eq!(cmd_loads, 1);
        assert_eq!(cmd_reuses, batches.len() as u64 - 1);
    }

    #[test]
    fn worker_survives_panicking_request() {
        let repo = tiny_repo();
        let sched = Scheduler::new();
        let mut rng = Rng::new(2);
        // Request 0: right shape header but truncated data — the
        // forward indexes out of bounds and panics mid-layer.
        sched.push(InferenceRequest::new(0, Tensor { h: 6, w: 6, c: 3, data: vec![0.5; 10] }));
        sched.push(good_request(1, &mut rng));
        sched.close();
        let (tx, rx) = mpsc::channel();
        run_worker(
            0,
            &repo,
            crate::hw::usb::UsbLink::usb3_frontpanel(),
            &sched,
            &BatchPolicy::single(),
            4,
            0,
            &Hub::new(1),
            &tx,
        );
        drop(tx);
        let mut failed = Vec::new();
        let mut done = Vec::new();
        for ev in rx {
            match ev {
                WorkerEvent::Done(r) => done.push(r.id),
                WorkerEvent::Failed(f) => {
                    assert!(f.error.contains("panicked"), "error: {}", f.error);
                    failed.push(f.id);
                }
                WorkerEvent::Batch(_) => {}
            }
        }
        assert_eq!(failed, vec![0]);
        assert_eq!(done, vec![1], "worker must keep serving after a panic");
    }

    #[test]
    fn unknown_network_fails_the_batch_not_the_worker() {
        let repo = tiny_repo();
        let sched = Scheduler::new();
        let mut rng = Rng::new(3);
        sched.push(good_request(0, &mut rng).for_network("ghost"));
        sched.push(good_request(1, &mut rng));
        sched.close();
        let (tx, rx) = mpsc::channel();
        run_worker(
            0,
            &repo,
            crate::hw::usb::UsbLink::usb3_frontpanel(),
            &sched,
            &BatchPolicy::single(),
            4,
            0,
            &Hub::new(1),
            &tx,
        );
        drop(tx);
        let mut failed = Vec::new();
        let mut done = Vec::new();
        for ev in rx {
            match ev {
                WorkerEvent::Done(r) => done.push(r.id),
                WorkerEvent::Failed(f) => {
                    assert!(f.error.contains("ghost"), "error: {}", f.error);
                    failed.push(f.id);
                }
                WorkerEvent::Batch(_) => {}
            }
        }
        assert_eq!(failed, vec![0]);
        assert_eq!(done, vec![1]);
    }

    #[test]
    fn conformance_sampling_is_clean_on_an_honest_model() {
        let repo = tiny_repo();
        let sched = Scheduler::new();
        let mut rng = Rng::new(5);
        sched.push_all((0..4).map(|id| good_request(id, &mut rng)));
        sched.close();
        let (tx, rx) = mpsc::channel();
        run_worker(
            0,
            &repo,
            crate::hw::usb::UsbLink::usb3_frontpanel(),
            &sched,
            &BatchPolicy::single(),
            4,
            1, // check every batch
            &Hub::new(1),
            &tx,
        );
        drop(tx);
        let mut checked = 0;
        for ev in rx {
            if let WorkerEvent::Batch(m) = ev {
                assert!(m.conformance_checked, "sample=1 checks every batch");
                assert_eq!(m.drift_events, 0, "honest model must not drift");
                assert!(m.resfifo_peak > 0, "device observed RESFIFO occupancy");
                assert!(m.data_peak_words > 0 && m.weight_peak_words > 0);
                checked += 1;
            }
        }
        assert_eq!(checked, 4);
    }

    #[test]
    fn traced_batch_records_queue_forward_layer_and_postprocess_spans() {
        let repo = tiny_repo();
        let sched = Scheduler::new();
        let mut rng = Rng::new(4);
        let hub = Hub::new(1);
        hub.set_tracing(true);
        let trace = hub.start_trace(0, 1).expect("tracing is on");
        sched.push(good_request(0, &mut rng).with_trace(trace.clone()));
        sched.close();
        let (tx, rx) = mpsc::channel();
        run_worker(
            0,
            &repo,
            crate::hw::usb::UsbLink::usb3_frontpanel(),
            &sched,
            &BatchPolicy::single(),
            4,
            0,
            &hub,
            &tx,
        );
        drop(tx);
        assert!(rx.iter().any(|ev| matches!(ev, WorkerEvent::Done(_))));
        hub.finish(&trace);
        let traces = hub.drain();
        assert_eq!(traces.len(), 1);
        let t = &traces[0];
        assert_eq!(t.verdict, Verdict::Served);
        assert_eq!(t.worker, Some(0), "finished on worker 0's ring");
        assert_eq!((t.batch_size, t.streak), (1, 1));
        let names: Vec<&str> = t.spans.iter().map(|s| s.name.as_str()).collect();
        for want in ["queue", "forward", "layer c1", "layer gap", "postprocess"] {
            assert!(names.contains(&want), "span {want:?} missing from {names:?}");
        }
        // Layer sub-spans sit inside the forward span.
        let fwd = t.spans.iter().find(|s| s.name == "forward").unwrap();
        for s in t.spans.iter().filter(|s| s.name.starts_with("layer ")) {
            assert!(s.start_us >= fwd.start_us, "layer starts inside forward");
            assert!(s.start_us + s.dur_us <= fwd.start_us + fwd.dur_us + 1, "layer ends inside forward");
        }
        // And the hub aggregated the per-layer counter families.
        let fams = hub.layer_families();
        assert!(fams.iter().any(|(net, layer, f)| net == "w" && layer == "c1" && f.passes > 0));
    }
}
