//! Device worker: owns one simulated [`StreamAccelerator`], drains the
//! shared queue into micro-batches and forwards them.
//!
//! Batches of one ride the classic single-image
//! [`HostDriver::forward`] path (the `batch=1` degenerate case);
//! larger batches go through the weight-resident
//! [`forward_batch`] so each weight super-block crosses the link once
//! per batch. A failing or panicking forward no longer takes the whole
//! run down: the device is re-created (its caches and FIFOs may be
//! mid-flight) and a failed *multi-request* batch is retried member by
//! member so only the truly poisoned requests are reported failed —
//! innocent requests that merely shared a batch still get answers, and
//! completed responses are always drained.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::accel::stream::StreamAccelerator;
use crate::host::batch::forward_batch;
use crate::host::driver::HostDriver;
use crate::host::postprocess;
use crate::hw::clock::ClockDomain;
use crate::hw::usb::UsbLink;
use crate::net::graph::Network;
use crate::net::tensor::TensorF32;
use crate::net::weights::Blobs;

use super::batcher::{self, BatchPolicy};
use super::metrics::FailedRequest;
use super::scheduler::{QueuedRequest, Scheduler};
use super::InferenceResponse;

/// What a worker reports back to the coordinator.
pub(crate) enum WorkerEvent {
    /// One request finished.
    Done(InferenceResponse),
    /// One micro-batch finished (metrics only).
    Batch(BatchMetric),
    /// One request failed (forward error or panic).
    Failed(FailedRequest),
}

/// Per-batch accounting emitted by a worker.
#[derive(Clone, Debug)]
pub(crate) struct BatchMetric {
    pub worker: usize,
    pub size: usize,
    /// Modeled link seconds this batch added on this worker's device.
    pub link_seconds: f64,
    /// Modeled engine seconds this batch added.
    pub engine_seconds: f64,
    /// Host wall seconds inside the forward.
    pub service_seconds: f64,
    pub weight_loads: u64,
    pub weight_sweeps: u64,
}

/// Everything a worker needs besides the device and the batch at hand.
struct WorkerCtx<'a> {
    worker: usize,
    net: &'a Network,
    blobs: &'a Blobs,
    link: UsbLink,
    tx: &'a mpsc::Sender<WorkerEvent>,
}

/// Run one worker until the queue closes. Never panics outward; errors
/// surface as [`WorkerEvent::Failed`].
pub(crate) fn run_worker(
    worker: usize,
    net: &Network,
    blobs: &Blobs,
    link: UsbLink,
    sched: &Scheduler,
    policy: &BatchPolicy,
    tx: &mpsc::Sender<WorkerEvent>,
) {
    let ctx = WorkerCtx { worker, net, blobs, link, tx };
    let mut dev = StreamAccelerator::new(link);
    while let Some(batch) = batcher::next_batch(sched, policy) {
        if !run_batch(&mut dev, &ctx, &batch) {
            return; // coordinator went away
        }
    }
}

/// Forward one micro-batch and report results. On failure the device is
/// re-created and a multi-request batch is retried member by member, so
/// only truly poisoned requests fail. Returns `false` when the response
/// channel is gone (coordinator dropped).
fn run_batch(dev: &mut StreamAccelerator, ctx: &WorkerCtx, batch: &[QueuedRequest]) -> bool {
    let size = batch.len();
    let images: Vec<TensorF32> = batch.iter().map(|q| q.request.image.clone()).collect();
    let link_before = dev.usb.total_seconds();
    let engine_before = ClockDomain::ENGINE.secs(dev.stats.cycles);
    let loads_before = dev.stats.weight_loads;
    let sweeps_before = dev.stats.weight_sweeps;
    let t0 = Instant::now();
    let outcome =
        match catch_unwind(AssertUnwindSafe(|| forward_probs(dev, ctx.net, ctx.blobs, &images))) {
            Ok(Ok(probs)) => Ok(probs),
            Ok(Err(err)) => Err(format!("{err:#}")),
            Err(panic) => Err(panic_message(panic.as_ref())),
        };
    let service_seconds = t0.elapsed().as_secs_f64();
    match outcome {
        Ok(all_probs) => {
            let link_seconds = dev.usb.total_seconds() - link_before;
            let engine_seconds = ClockDomain::ENGINE.secs(dev.stats.cycles) - engine_before;
            let modeled_each = (link_seconds + engine_seconds) / size as f64;
            for (q, probs) in batch.iter().zip(all_probs) {
                let argmax = postprocess::argmax(&probs).unwrap_or(0);
                let done = WorkerEvent::Done(InferenceResponse {
                    id: q.request.id,
                    probs,
                    argmax,
                    worker: ctx.worker,
                    service_seconds,
                    modeled_seconds: modeled_each,
                    queue_wait_seconds: q.queue_wait,
                    batch_size: size,
                });
                if ctx.tx.send(done).is_err() {
                    return false;
                }
            }
            let metric = BatchMetric {
                worker: ctx.worker,
                size,
                link_seconds,
                engine_seconds,
                service_seconds,
                weight_loads: dev.stats.weight_loads - loads_before,
                weight_sweeps: dev.stats.weight_sweeps - sweeps_before,
            };
            ctx.tx.send(WorkerEvent::Batch(metric)).is_ok()
        }
        Err(error) => {
            // The device may be mid-transfer: start from a clean one.
            *dev = StreamAccelerator::new(ctx.link);
            if size == 1 {
                fail_batch(batch, ctx.worker, error, ctx.tx).is_ok()
            } else {
                // Don't let one poisoned request fail its batch-mates:
                // replay each member alone (recursion depth is 1).
                for q in batch {
                    if !run_batch(dev, ctx, std::slice::from_ref(q)) {
                        return false;
                    }
                }
                true
            }
        }
    }
}

/// Forward a batch and return per-image softmax probabilities.
fn forward_probs(
    dev: &mut StreamAccelerator,
    net: &Network,
    blobs: &Blobs,
    images: &[TensorF32],
) -> Result<Vec<Vec<f32>>> {
    if images.len() == 1 {
        let r = HostDriver::new(dev).forward(net, blobs, &images[0])?;
        Ok(vec![r.probs])
    } else {
        let b = forward_batch(dev, net, blobs, images)?;
        Ok(b.items.into_iter().map(|i| i.probs).collect())
    }
}

fn fail_batch(
    batch: &[QueuedRequest],
    worker: usize,
    error: String,
    tx: &mpsc::Sender<WorkerEvent>,
) -> Result<(), mpsc::SendError<WorkerEvent>> {
    for q in batch {
        tx.send(WorkerEvent::Failed(FailedRequest {
            id: q.request.id,
            worker,
            error: error.clone(),
        }))?;
    }
    Ok(())
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        format!("worker panicked: {s}")
    } else if let Some(s) = payload.downcast_ref::<String>() {
        format!("worker panicked: {s}")
    } else {
        "worker panicked".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::InferenceRequest;
    use crate::net::layer::LayerSpec;
    use crate::net::tensor::Tensor;
    use crate::net::weights::synthesize_weights;
    use crate::prop::Rng;

    fn tiny_net() -> Network {
        let mut n = Network::new("w");
        let inp = n.input(6, 3);
        let c1 = n.engine(LayerSpec::conv("c1", 3, 1, 0, 6, 3, 8, 0), inp);
        let gap = n.engine(LayerSpec::avgpool("gap", 4, 1, 4, 8), c1);
        n.softmax("prob", gap);
        n
    }

    fn good_request(id: u64, rng: &mut Rng) -> InferenceRequest {
        InferenceRequest {
            id,
            image: Tensor::from_vec(6, 6, 3, (0..6 * 6 * 3).map(|_| rng.normal(1.0)).collect()),
        }
    }

    #[test]
    fn worker_drains_queue_and_reports_metrics() {
        let net = tiny_net();
        let blobs = synthesize_weights(&net, 3);
        let sched = Scheduler::new();
        let mut rng = Rng::new(1);
        sched.push_all((0..5).map(|id| good_request(id, &mut rng)));
        sched.close();
        let (tx, rx) = mpsc::channel();
        run_worker(
            0,
            &net,
            &blobs,
            crate::hw::usb::UsbLink::usb3_frontpanel(),
            &sched,
            &BatchPolicy::batched(4),
            &tx,
        );
        drop(tx);
        let mut done = 0;
        let mut batches = Vec::new();
        for ev in rx {
            match ev {
                WorkerEvent::Done(r) => {
                    assert_eq!(r.worker, 0);
                    assert!(r.modeled_seconds > 0.0);
                    done += 1;
                }
                WorkerEvent::Batch(m) => batches.push(m.size),
                WorkerEvent::Failed(f) => panic!("unexpected failure: {}", f.error),
            }
        }
        assert_eq!(done, 5);
        assert_eq!(batches.iter().sum::<usize>(), 5);
        assert!(batches.len() >= 2, "4+1 expected, got {batches:?}");
    }

    #[test]
    fn worker_survives_panicking_request() {
        let net = tiny_net();
        let blobs = synthesize_weights(&net, 3);
        let sched = Scheduler::new();
        let mut rng = Rng::new(2);
        // Request 0: right shape header but truncated data — the
        // forward indexes out of bounds and panics mid-layer.
        sched.push(InferenceRequest {
            id: 0,
            image: Tensor { h: 6, w: 6, c: 3, data: vec![0.5; 10] },
        });
        sched.push(good_request(1, &mut rng));
        sched.close();
        let (tx, rx) = mpsc::channel();
        run_worker(
            0,
            &net,
            &blobs,
            crate::hw::usb::UsbLink::usb3_frontpanel(),
            &sched,
            &BatchPolicy::single(),
            &tx,
        );
        drop(tx);
        let mut failed = Vec::new();
        let mut done = Vec::new();
        for ev in rx {
            match ev {
                WorkerEvent::Done(r) => done.push(r.id),
                WorkerEvent::Failed(f) => {
                    assert!(f.error.contains("panicked"), "error: {}", f.error);
                    failed.push(f.id);
                }
                WorkerEvent::Batch(_) => {}
            }
        }
        assert_eq!(failed, vec![0]);
        assert_eq!(done, vec![1], "worker must keep serving after a panic");
    }
}
