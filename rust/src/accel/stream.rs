//! The stream accelerator (Figs 22, 35): CMDFIFO + RESFIFO + three BRAM
//! caches + the engine, fed directly by the host over USB3.0 — the
//! architecture the paper ships (§3.4.2 picks it over the generic
//! DRAM-based design).
//!
//! The device is passive: the host drives the Fig 35 flow — load
//! commands, then per layer / per piece: load bias+weights, load a GEMM
//! data slice, pulse `restart_engine`, read RESFIFO. Every USB transfer
//! is routed through the [`UsbPort`] model so the S5 timing bench can
//! replay the exact traffic; every BRAM/FIFO access is counted by the
//! hardware models.

use anyhow::{bail, ensure, Result};

use crate::engine::csb::Csb;
use crate::fp16::F16;
use crate::hw::bram::{Bram, Word128};
use crate::hw::fifo::Fifo;
use crate::hw::serdes::Serdes;
use crate::hw::usb::{Endpoint, UsbLink, UsbPort};
use crate::net::layer::{LayerSpec, OpType};

/// Data cache: 128 bits × 1024 (§4.4).
pub const DATA_CACHE_WORDS: usize = 1024;
/// Weight cache: 128 bits × 8192.
pub const WEIGHT_CACHE_WORDS: usize = 8192;
/// Bias cache: 128 bits × 1024.
pub const BIAS_CACHE_WORDS: usize = 1024;
/// Result FIFO: 32 bits × 1024.
pub const RES_FIFO_DEPTH: usize = 1024;

/// What the engine should compute from the current cache contents —
/// the per-piece state the CSB derives from the layer register plus the
/// host's slicing (Fig 35 "by layer and by piece").
#[derive(Clone, Debug)]
pub struct SliceTask {
    pub op: OpType,
    pub k: usize,
    pub stride: usize,
    /// Output elements along x this pass.
    pub out_cols: usize,
    /// Input-channel groups resident in the data cache.
    pub groups: usize,
    /// Output channels this pass (conv; pooling processes one 8-lane
    /// group per pass).
    pub oc_count: usize,
    /// Word pitch of one data row in the cache.
    pub data_width: usize,
    /// Rows resident (may be < k for a clipped ceil-mode pool window).
    pub data_rows: usize,
    /// Pixel mode: the data cache holds a single k×k window.
    pub pixel_mode: bool,
    /// kernel_size register value (avg-pool divisor).
    pub kernel_size_reg: u32,
    pub skip_relu: bool,
    /// Word offset of this pass's weights in the weight cache (several
    /// 8-channel blocks can be resident at once — the host loads a
    /// super-block and sweeps passes over it, which is how Table 2's
    /// "data transferred once" accounting comes about).
    pub weight_base: usize,
    /// Index offset of this pass's biases in the bias cache.
    pub bias_base: usize,
    /// Virtual pooling padding (GoogLeNet-style "same" pooling): window
    /// elements at col/row < pad or beyond the surface are skipped.
    pub pool_pad: usize,
    /// Word offset of this pass's data slice in the data cache — the
    /// data-side mirror of `weight_base`. The batched host loads several
    /// images' slices side by side in one transfer and sweeps the engine
    /// across them, so per-transaction link latency is paid once per
    /// group of images instead of once per image.
    pub data_base: usize,
}

/// Accumulated engine-side counters.
#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    /// Engine-clock cycles (closed-form per slice, validated against the
    /// cycle-accurate simulator — see `engine::timed`).
    pub cycles: u64,
    /// Engine passes (restart_engine pulses).
    pub passes: u64,
    /// Interrupts raised (one per completed pass).
    pub interrupts: u64,
    /// Weight-cache load transfers (one per `load_weights` call).
    pub weight_loads: u64,
    /// Conv engine passes that swept resident weights. Together with
    /// `weight_loads` this measures how far batching amortizes weight
    /// traffic: sequential serving reloads per image, batched serving
    /// sweeps many passes per load.
    pub weight_sweeps: u64,
    /// Weight super-blocks found still resident under their content key
    /// (see [`StreamAccelerator::load_weight_block_cached`]) — loads
    /// that crossed **zero** link bytes because a previous batch of the
    /// same artifact left the block in the cache.
    pub weight_reuses: u64,
    /// Command streams loaded over the link (CMDFIFO fills that crossed
    /// USB). Multi-network serving wants this *below* the request count:
    /// the compiler's artifact ids let a worker reload commands only on
    /// a network switch.
    pub command_loads: u64,
    /// Command streams replayed from the device-side shadow without any
    /// link traffic (same artifact as the previous load).
    pub command_reuses: u64,
    /// Drain-barrier stalls: host-side passes where RESFIFO lacked the
    /// space for the next slice's results, forcing an early drain before
    /// the engine could be restarted. The batched driver increments this
    /// at each forced-drain site; real RTL would count the cycles its
    /// `wr_en` sat gated on `full`.
    pub drain_stalls: u64,
}

impl EngineStats {
    /// Conv passes per weight load — the weight-cache reuse factor the
    /// batched host driver exists to raise.
    pub fn weight_reuse(&self) -> f64 {
        if self.weight_loads == 0 {
            0.0
        } else {
            self.weight_sweeps as f64 / self.weight_loads as f64
        }
    }
}

/// Peak-occupancy watermarks — the FIFO/BRAM high-water counters real
/// RTL carries for depth sizing (§4.4). Unlike [`EngineStats`] these are
/// maxima, not monotone counters: two snapshots cannot be diffed into a
/// window's peak, so the device keeps three independently resettable
/// trackers (device lifetime, per observation window, per layer).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Watermarks {
    /// Highest RESFIFO occupancy (results awaiting drain).
    pub resfifo: u64,
    /// Highest CMDFIFO occupancy in dwords (3 per queued layer).
    pub cmdfifo: u64,
    /// Highest data-cache extent touched, in 128-bit words.
    pub data_words: u64,
    /// Highest weight-cache extent touched, in 128-bit words.
    pub weight_words: u64,
}

impl Watermarks {
    /// Fold another window's peaks into this one (element-wise max).
    pub fn merge_max(&mut self, o: &Watermarks) {
        self.resfifo = self.resfifo.max(o.resfifo);
        self.cmdfifo = self.cmdfifo.max(o.cmdfifo);
        self.data_words = self.data_words.max(o.data_words);
        self.weight_words = self.weight_words.max(o.weight_words);
    }
}

/// The device.
pub struct StreamAccelerator {
    pub csb: Csb,
    pub res_fifo: Fifo<F16>,
    pub data_cache: Bram<Word128>,
    pub weight_cache: Bram<Word128>,
    pub bias_cache: Bram<Word128>,
    pub usb: UsbPort,
    pub stats: EngineStats,
    /// Current layer register (decoded by the CSB).
    pub layer: Option<LayerSpec>,
    /// §Perf step 3: pre-widened shadows of the data/weight caches,
    /// updated once per load instead of once per engine pass. Pure
    /// simulator acceleration — values are exactly the cache contents.
    data_f64: Vec<f64>,
    weight_f64: Vec<f64>,
    /// Device-side shadow of the last command stream loaded via
    /// [`Self::load_commands_cached`]: (artifact key, encoded dwords).
    /// CMDFIFO itself drains as the engine runs; the shadow lets the
    /// host replay an unchanged stream without re-crossing the link.
    cmd_shadow: Option<(String, Vec<u32>)>,
    /// Weight-side mirror of the command shadow: which keyed weight
    /// super-blocks are still resident, and the cache ranges they own.
    /// Any load that overlaps a region evicts it; a keyed load whose
    /// region is intact skips the link entirely (`weight_reuses`).
    weight_shadow: Vec<WeightRegion>,
    /// Telemetry layer tape: one mark per [`Self::load_layer`] while a
    /// worker has armed it (see [`Self::begin_layer_tape`]). Disarmed by
    /// default, so non-serving users (benches, unit tests, the classic
    /// driver flow) record nothing and pay nothing.
    tape: Vec<LayerMark>,
    tape_armed: bool,
    /// Device-lifetime peak occupancies (never reset).
    wm_total: Watermarks,
    /// Peaks since the last [`Self::begin_occupancy_window`] — the
    /// serving worker resets this per batch and checks the result
    /// against the static verifier's worst-case bounds.
    wm_window: Watermarks,
    /// Peaks since the current layer was loaded; folded retroactively
    /// into the previous [`LayerMark`] when the next layer begins.
    wm_layer: Watermarks,
}

/// Marks retained per armed forward — far above any supported command
/// stream's layer count, but a hard bound so the tape can never grow
/// without limit inside one forward.
const TAPE_CAP: usize = 4096;

/// Engine counters + link bytes snapshotted at layer entry; consecutive
/// marks diff into per-layer deltas (see
/// [`StreamAccelerator::take_layer_deltas`]).
#[derive(Clone, Debug)]
struct LayerMark {
    name: String,
    at: std::time::Instant,
    stats: EngineStats,
    bytes: u64,
    /// Peak occupancies observed *during* this layer — filled in
    /// retroactively when the next layer begins (or at drain time for
    /// the final layer), because a watermark is a max over the window,
    /// not a counter that can be diffed between marks.
    wm: Watermarks,
}

/// One shadowed weight super-block: its content key plus the weight-
/// and bias-cache ranges it occupies.
#[derive(Clone, Debug)]
struct WeightRegion {
    key: String,
    wbase: usize,
    wwords: usize,
    bbase: usize,
    bslots: usize,
}

fn ranges_overlap(a0: usize, alen: usize, b0: usize, blen: usize) -> bool {
    a0 < b0 + blen && b0 < a0 + alen
}

impl StreamAccelerator {
    pub fn new(link: UsbLink) -> StreamAccelerator {
        StreamAccelerator {
            csb: Csb::new(),
            res_fifo: Fifo::new("RESFIFO", RES_FIFO_DEPTH),
            data_cache: Bram::new("data_cache", DATA_CACHE_WORDS),
            weight_cache: Bram::new("weight_cache", WEIGHT_CACHE_WORDS),
            bias_cache: Bram::new("bias_cache", BIAS_CACHE_WORDS),
            usb: UsbPort::new(UsbLink { ..link }),
            stats: EngineStats::default(),
            layer: None,
            data_f64: vec![0.0; DATA_CACHE_WORDS * 8],
            weight_f64: vec![0.0; WEIGHT_CACHE_WORDS * 8],
            cmd_shadow: None,
            weight_shadow: Vec::new(),
            tape: Vec::new(),
            tape_armed: false,
            wm_total: Watermarks::default(),
            wm_window: Watermarks::default(),
            wm_layer: Watermarks::default(),
        }
    }

    /// Record an occupancy observation into all three watermark
    /// trackers (element selected by `f`).
    fn note_wm(&mut self, f: fn(&mut Watermarks) -> &mut u64, v: u64) {
        for wm in [&mut self.wm_total, &mut self.wm_window, &mut self.wm_layer] {
            let slot = f(wm);
            *slot = (*slot).max(v);
        }
    }

    /// Device-lifetime peak occupancies.
    pub fn watermarks(&self) -> Watermarks {
        self.wm_total
    }

    /// Reset the per-window watermark tracker. The serving worker calls
    /// this before each batch forward and reads
    /// [`Self::occupancy_window`] after, giving per-batch peaks to check
    /// against the verifier's worst-case occupancy bounds.
    pub fn begin_occupancy_window(&mut self) {
        self.wm_window = Watermarks::default();
    }

    /// Peak occupancies since the last [`Self::begin_occupancy_window`].
    pub fn occupancy_window(&self) -> Watermarks {
        self.wm_window
    }

    /// Load the full command stream (Fig 36 "Load Commands"): one USB
    /// block transfer of 12 bytes per layer. A keyless load invalidates
    /// the command shadow — the host did not claim an artifact identity.
    pub fn load_commands(&mut self, layers: &[&LayerSpec]) -> Result<()> {
        self.cmd_shadow = None;
        for spec in layers {
            ensure!(self.csb.load_command(spec), "CMDFIFO overflow at {}", spec.name);
        }
        self.stats.command_loads += 1;
        let queued = self.csb.cmd_fifo.len() as u64;
        self.note_wm(|w| &mut w.cmdfifo, queued);
        self.usb.transfer(Endpoint::PipeIn, 12 * layers.len() as u64);
        Ok(())
    }

    /// Load a command stream under a content-addressed artifact key
    /// (see [`crate::compiler`]). If `key` matches the stream already
    /// shadowed on the device, the CMDFIFO is refilled from the shadow
    /// with **no** link traffic (`command_reuses`); otherwise this is a
    /// full [`Self::load_commands`] and the shadow is replaced. This is
    /// what makes a network *switch* the only event that pays command
    /// transfer time in multi-network serving.
    pub fn load_commands_cached(&mut self, key: &str, layers: &[&LayerSpec]) -> Result<()> {
        if let Some((k, dwords)) = &self.cmd_shadow {
            if k == key {
                let dwords = dwords.clone();
                ensure!(self.csb.load_raw(&dwords), "CMDFIFO overflow replaying cached stream {key}");
                self.stats.command_reuses += 1;
                let queued = self.csb.cmd_fifo.len() as u64;
                self.note_wm(|w| &mut w.cmdfifo, queued);
                return Ok(());
            }
        }
        let mut dwords = Vec::with_capacity(3 * layers.len());
        for spec in layers {
            dwords.extend(spec.encode());
        }
        self.load_commands(layers)?;
        self.cmd_shadow = Some((key.to_string(), dwords));
        Ok(())
    }

    /// Advance the CSB to the next layer (Fig 36 "Load Layer").
    pub fn load_layer(&mut self) -> Option<LayerSpec> {
        let spec = self.csb.next_layer()?;
        // Close the outgoing layer's watermark window: its peaks belong
        // to the mark opened at its entry. (An epoch refill between two
        // layers is likewise attributed to the layer the engine was on
        // when the CMDFIFO was topped up.)
        if let Some(prev) = self.tape.last_mut() {
            prev.wm.merge_max(&self.wm_layer);
        }
        self.wm_layer = Watermarks::default();
        if self.tape_armed && self.tape.len() < TAPE_CAP {
            self.tape.push(LayerMark {
                name: spec.name.clone(),
                at: std::time::Instant::now(),
                stats: self.stats.clone(),
                bytes: self.usb.total_bytes(),
                wm: Watermarks::default(),
            });
        }
        self.layer = Some(spec.clone());
        Some(spec)
    }

    /// Arm the telemetry layer tape for the next forward: every
    /// subsequent [`Self::load_layer`] snapshots the engine counters at
    /// layer entry. The serving worker arms before each batch forward
    /// and drains with [`Self::take_layer_deltas`] after.
    pub fn begin_layer_tape(&mut self) {
        self.tape.clear();
        self.tape_armed = true;
    }

    /// Drain the armed layer tape into per-layer stat deltas: mark *i*'s
    /// counters diff against mark *i+1*'s (the final layer diffs against
    /// the live counters), so each row is exactly what that engine layer
    /// cost — passes, cycles, weight traffic, link bytes, host wall
    /// time. Disarms the tape.
    pub fn take_layer_deltas(&mut self) -> Vec<crate::telemetry::LayerStat> {
        let mut marks = std::mem::take(&mut self.tape);
        self.tape_armed = false;
        // The final layer's watermark window is still open — close it.
        if let Some(last) = marks.last_mut() {
            last.wm.merge_max(&self.wm_layer);
        }
        self.wm_layer = Watermarks::default();
        let end_at = std::time::Instant::now();
        let end_bytes = self.usb.total_bytes();
        let mut out = Vec::with_capacity(marks.len());
        for i in 0..marks.len() {
            let (next_stats, next_bytes, next_at) = match marks.get(i + 1) {
                Some(n) => (n.stats.clone(), n.bytes, n.at),
                None => (self.stats.clone(), end_bytes, end_at),
            };
            let m = &marks[i];
            out.push(crate::telemetry::LayerStat {
                name: m.name.clone(),
                passes: next_stats.passes - m.stats.passes,
                cycles: next_stats.cycles - m.stats.cycles,
                weight_loads: next_stats.weight_loads - m.stats.weight_loads,
                weight_reuses: next_stats.weight_reuses - m.stats.weight_reuses,
                link_bytes: next_bytes - m.bytes,
                resfifo_peak: m.wm.resfifo,
                cmdfifo_peak: m.wm.cmdfifo,
                data_peak_words: m.wm.data_words,
                weight_peak_words: m.wm.weight_words,
                stall_passes: next_stats.drain_stalls - m.stats.drain_stalls,
                epoch_reloads: (next_stats.command_loads + next_stats.command_reuses)
                    - (m.stats.command_loads + m.stats.command_reuses),
                start: m.at,
                dur_us: next_at.saturating_duration_since(m.at).as_micros() as u64,
            });
        }
        out
    }

    /// Pipe a block of FP16 values into a cache. Each value moves as a
    /// 32-bit USB word (low 16 bits valid, §4.4) and is SERDES-packed
    /// into 128-bit cache words.
    fn pipe_in(&mut self, which: Cache, base_word: usize, values: &[F16]) -> Result<()> {
        let words = Serdes::pack_stream(values);
        let cache = match which {
            Cache::Data => &mut self.data_cache,
            Cache::Weight => &mut self.weight_cache,
            Cache::Bias => &mut self.bias_cache,
        };
        ensure!(
            base_word + words.len() <= cache.depth(),
            "{} overflow: {} + {} words",
            cache.name(),
            base_word,
            words.len()
        );
        cache.load(base_word, &words);
        // Maintain the pre-widened shadow (see struct docs).
        let shadow = match which {
            Cache::Data => Some(&mut self.data_f64),
            Cache::Weight => Some(&mut self.weight_f64),
            Cache::Bias => None,
        };
        if let Some(shadow) = shadow {
            for (wi, word) in words.iter().enumerate() {
                let base = (base_word + wi) * 8;
                for (l, v) in word.iter().enumerate() {
                    shadow[base + l] = v.to_f64();
                }
            }
        }
        let extent = (base_word + words.len()) as u64;
        match which {
            Cache::Data => self.note_wm(|w| &mut w.data_words, extent),
            Cache::Weight => self.note_wm(|w| &mut w.weight_words, extent),
            Cache::Bias => {}
        }
        self.usb.transfer(Endpoint::PipeIn, 4 * values.len() as u64);
        Ok(())
    }

    /// Load a GEMM data slice ("Load Gemm").
    pub fn load_data(&mut self, values: &[F16]) -> Result<()> {
        self.pipe_in(Cache::Data, 0, values)
    }

    /// Load a weight block ("load weight & bias") at word 0. The bias
    /// cache stores one value per word (only the low 16 bits of each
    /// 128-bit word are valid, §4.4) — so bias values are loaded one
    /// word each.
    pub fn load_weights(&mut self, values: &[F16]) -> Result<()> {
        self.load_weights_at(0, values)
    }

    /// Load a weight block at an arbitrary word base. Any shadowed
    /// super-block the write overlaps is evicted — a keyless load makes
    /// no residency claim.
    pub fn load_weights_at(&mut self, base: usize, values: &[F16]) -> Result<()> {
        let words = values.len().div_ceil(8);
        self.weight_shadow.retain(|r| !ranges_overlap(r.wbase, r.wwords, base, words));
        self.stats.weight_loads += 1;
        self.pipe_in(Cache::Weight, base, values)
    }

    pub fn load_bias(&mut self, values: &[F16]) -> Result<()> {
        self.load_bias_at(0, values)
    }

    /// Load biases starting at slot `base`, evicting overlapped shadow
    /// regions (by their bias range).
    pub fn load_bias_at(&mut self, base: usize, values: &[F16]) -> Result<()> {
        ensure!(base + values.len() <= BIAS_CACHE_WORDS, "bias cache overflow");
        self.weight_shadow.retain(|r| !ranges_overlap(r.bbase, r.bslots, base, values.len()));
        for (i, &b) in values.iter().enumerate() {
            let mut w = [F16::ZERO; 8];
            w[0] = b;
            self.bias_cache.write(base + i, w);
        }
        // Each bias still crosses USB as a 32-bit word, padded to a full
        // 128-bit cache word device-side.
        self.usb.transfer(Endpoint::PipeIn, 4 * values.len() as u64);
        Ok(())
    }

    /// Whether the keyed super-block is still resident at exactly these
    /// cache ranges. Counts a `weight_reuses` on a hit — this is the
    /// zero-cost pre-check that lets the host skip not just the link
    /// transfer but the host-side weight gather too.
    pub fn weight_block_resident(
        &mut self,
        key: &str,
        wbase: usize,
        wwords: usize,
        bbase: usize,
        bslots: usize,
    ) -> bool {
        let hit = self.weight_shadow.iter().any(|r| {
            r.key == key && r.wbase == wbase && r.wwords == wwords && r.bbase == bbase && r.bslots == bslots
        });
        if hit {
            self.stats.weight_reuses += 1;
        }
        hit
    }

    /// Load a weight super-block + its biases under a content key — the
    /// weight-side mirror of [`Self::load_commands_cached`]. If the
    /// keyed region is still resident at exactly these bases (nothing
    /// overwrote it since a previous batch of the same artifact), both
    /// transfers are skipped with **zero** link traffic and the call
    /// counts as a `weight_reuses`; otherwise the block loads normally
    /// and is shadowed. Returns whether the block was resident.
    pub fn load_weight_block_cached(
        &mut self,
        key: &str,
        wbase: usize,
        weights: &[F16],
        bbase: usize,
        bias: &[F16],
    ) -> Result<bool> {
        let wwords = weights.len().div_ceil(8);
        if self.weight_block_resident(key, wbase, wwords, bbase, bias.len()) {
            return Ok(true);
        }
        self.load_weights_at(wbase, weights)?;
        self.load_bias_at(bbase, bias)?;
        self.weight_shadow.push(WeightRegion {
            key: key.to_string(),
            wbase,
            wwords,
            bbase,
            bslots: bias.len(),
        });
        Ok(false)
    }

    /// "Restart Engine": compute one slice from the resident caches,
    /// pushing results into RESFIFO. Returns the number of results.
    pub fn restart_engine(&mut self, task: &SliceTask) -> Result<usize> {
        ensure!(self.layer.is_some(), "no layer loaded");
        let produced = match task.op {
            OpType::ConvRelu => self.run_conv_slice(task)?,
            OpType::MaxPool | OpType::AvgPool => self.run_pool_slice(task)?,
            OpType::Idle => 0,
        };
        self.stats.passes += 1;
        self.stats.interrupts += 1;
        // RESFIFO only grows between drains, so its occupancy right
        // after a pass is the running peak since the last read.
        let occupied = self.res_fifo.len() as u64;
        self.note_wm(|w| &mut w.resfifo, occupied);
        Ok(produced)
    }

    /// Wait-for-interrupt + "Read Output": drain `n` results over USB
    /// (32-bit words each, Fig 37's "between every two results there is a
    /// padded 0").
    pub fn read_results(&mut self, n: usize) -> Result<Vec<F16>> {
        // Interrupt check is a Wire Out read.
        self.usb.transfer(Endpoint::WireOut, 4);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            match self.res_fifo.pop() {
                Some(v) => out.push(v),
                None => bail!("RESFIFO underflow: asked {n}, had {}", out.len()),
            }
        }
        self.usb.transfer(Endpoint::PipeOut, 4 * n as u64);
        Ok(out)
    }

    // ---- engine internals ------------------------------------------------

    fn data_word(&mut self, ky: usize, x: usize, g: usize, task: &SliceTask) -> Word128 {
        let addr = if task.pixel_mode {
            (ky * task.k + x) * task.groups + g
        } else {
            (ky * task.data_width + x) * task.groups + g
        };
        self.data_cache.read(task.data_base + addr)
    }

    fn run_conv_slice(&mut self, task: &SliceTask) -> Result<usize> {
        let k = task.k;
        let k2 = k * k;
        ensure!(task.out_cols * task.oc_count <= self.res_fifo.space(), "RESFIFO would overflow");
        let mut produced = 0;

        // §Perf steps 2+3: the fused-rounding MAC chain (see
        // engine::functional) over the pre-widened cache shadows —
        // bit-identical to the word-by-word F16 loop. BRAM read counters
        // are bulk-updated with exactly the reads the per-cycle loop
        // would have issued.
        let data_words = if task.pixel_mode {
            k2 * task.groups
        } else {
            task.data_rows * task.data_width * task.groups
        };
        let weight_words = task.oc_count * k2 * task.groups;
        ensure!(
            task.data_base + data_words <= DATA_CACHE_WORDS,
            "data slice {} + {} words exceeds data cache",
            task.data_base,
            data_words
        );
        ensure!(
            task.weight_base + weight_words <= WEIGHT_CACHE_WORDS,
            "weight block {} + {} words exceeds weight cache",
            task.weight_base,
            weight_words
        );
        let din = &self.data_f64[task.data_base * 8..(task.data_base + data_words) * 8];
        let wdat = &self.weight_f64[task.weight_base * 8..(task.weight_base + weight_words) * 8];
        let lanes = task.groups * 8;

        // Fig 24 traversal: output channel outermost, then x, then the
        // channel groups, then the window.
        for oc in 0..task.oc_count {
            let bias = self.bias_cache.read(task.bias_base + oc)[0].to_f64();
            let wbase_oc = oc * k2 * lanes;
            for xo in 0..task.out_cols {
                let mut fsum = bias;
                for g in 0..task.groups {
                    let c0 = g * 8;
                    let mut psum = [0f64; 8];
                    for ky in 0..k {
                        for kx in 0..k {
                            let x = if task.pixel_mode { kx } else { xo * task.stride + kx };
                            let db = if task.pixel_mode {
                                (ky * k + x) * lanes + c0
                            } else {
                                (ky * task.data_width + x) * lanes + c0
                            };
                            let wb = wbase_oc + (ky * k + kx) * lanes + c0;
                            for l in 0..8 {
                                let prod = crate::fp16::round16_64(din[db + l] * wdat[wb + l]);
                                psum[l] = crate::fp16::round16_64(psum[l] + prod);
                            }
                        }
                    }
                    for p in psum {
                        fsum = crate::fp16::round16_64(fsum + p);
                    }
                }
                let v16 = F16::from_f64(fsum);
                let v = if task.skip_relu { v16 } else { v16.relu() };
                self.res_fifo.push_checked(v);
                produced += 1;
            }
        }
        // Model the per-cycle BRAM word reads the RTL issues.
        let word_reads = (task.out_cols * task.oc_count * task.groups * k2) as u64;
        self.data_cache.count_reads(word_reads);
        self.weight_cache.count_reads(word_reads);

        // Serialized-round slice timing (see perfmodel::layer_engine_cycles):
        // 3·k² + 2·8 + 10 cycles per (output element, channel group) round.
        let per_word = 3 * k2 as u64 + 26;
        self.stats.cycles += task.out_cols as u64 * task.oc_count as u64 * task.groups as u64 * per_word;
        self.stats.weight_sweeps += 1;
        Ok(produced)
    }

    fn run_pool_slice(&mut self, task: &SliceTask) -> Result<usize> {
        ensure!(task.groups == 1, "pooling processes one channel group per slice");
        ensure!(task.out_cols * 8 <= self.res_fifo.space(), "RESFIFO would overflow");
        ensure!(
            task.data_base + task.data_rows * task.data_width <= DATA_CACHE_WORDS,
            "pool slice {} + {} words exceeds data cache",
            task.data_base,
            task.data_rows * task.data_width
        );
        let divisor = F16::from_u32(task.kernel_size_reg);
        let mut produced = 0;
        let mut elems_total = 0u64;
        for xo in 0..task.out_cols {
            let mut acc = [F16::ZERO; 8];
            for ky in 0..task.data_rows {
                for kx in 0..task.k {
                    let x = (xo * task.stride + kx).wrapping_sub(task.pool_pad);
                    if x >= task.data_width {
                        continue; // clipped (left via wrap, right direct)
                    }
                    let d = self.data_word(ky, x, 0, task);
                    elems_total += 1;
                    for l in 0..8 {
                        acc[l] = match task.op {
                            OpType::MaxPool => {
                                if d[l].gt(acc[l]) {
                                    d[l]
                                } else {
                                    acc[l]
                                }
                            }
                            _ => acc[l].add(d[l]),
                        };
                    }
                }
            }
            for a in acc {
                let v = if task.op == OpType::AvgPool { a.div(divisor) } else { a };
                self.res_fifo.push_checked(v);
                produced += 1;
            }
        }
        let per_elem = 2u64; // II of the comparator/accumulator
        let tail = if task.op == OpType::AvgPool { 6 } else { 4 };
        self.stats.cycles += elems_total * per_elem + task.out_cols as u64 * tail;
        Ok(produced)
    }
}

enum Cache {
    Data,
    Weight,
    Bias,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::functional::{self, ConvWeightsF16};
    use crate::host::gemm;
    use crate::net::tensor::{ConvWeights, Tensor, TensorF16};
    use crate::prop::Rng;

    fn rand_tensor(rng: &mut Rng, side: usize, c: usize) -> TensorF16 {
        Tensor::from_vec(
            side,
            side,
            c,
            (0..side * side * c).map(|_| F16::from_f32(rng.normal(1.0))).collect(),
        )
    }

    #[test]
    fn conv_slice_matches_functional_row() {
        let mut rng = Rng::new(0x57AEA);
        let spec = LayerSpec::conv("t", 3, 1, 1, 6, 16, 8, 0);
        let mut w = ConvWeights::zeros(8, 3, 16);
        for v in w.data.iter_mut() {
            *v = rng.normal(0.3);
        }
        for b in w.bias.iter_mut() {
            *b = rng.normal(0.1);
        }
        let wf = ConvWeightsF16::from_f32(&w);
        let raw = rand_tensor(&mut rng, 6, 16);
        let padded = raw.to_f32().pad_surface(1).to_f16();
        let expect = functional::conv(&spec, &padded, &wf);

        let mut dev = StreamAccelerator::new(UsbLink::usb3_frontpanel());
        dev.load_commands(&[&spec]).unwrap();
        dev.load_layer().unwrap();
        dev.load_weights(&gemm::weight_block(&wf, 0, 8)).unwrap();
        dev.load_bias(&gemm::bias_block(&wf, 0, 8)).unwrap();
        for y in 0..spec.o_side as usize {
            let slice = gemm::conv_row_slice(&padded, y * spec.stride as usize, 3);
            dev.load_data(&slice).unwrap();
            let task = SliceTask {
                op: OpType::ConvRelu,
                k: 3,
                stride: 1,
                out_cols: 6,
                groups: 2,
                oc_count: 8,
                data_width: 8,
                data_rows: 3,
                pixel_mode: false,
                kernel_size_reg: 9,
                skip_relu: false,
                weight_base: 0,
                bias_base: 0,
                pool_pad: 0,
                data_base: 0,
            };
            let n = dev.restart_engine(&task).unwrap();
            assert_eq!(n, 6 * 8);
            let res = dev.read_results(n).unwrap();
            // Result order: oc outer, x inner.
            for oc in 0..8 {
                for x in 0..6 {
                    assert_eq!(
                        res[oc * 6 + x].to_bits(),
                        expect.get(y, x, oc).to_bits(),
                        "y={y} oc={oc} x={x}"
                    );
                }
            }
        }
        assert!(dev.stats.cycles > 0);
        assert_eq!(dev.stats.passes, 6);
        assert!(dev.usb.total_bytes() > 0);
    }

    #[test]
    fn pool_slice_matches_functional() {
        let mut rng = Rng::new(0x900);
        let spec = LayerSpec::maxpool("p", 3, 2, 9, 16);
        let inp = rand_tensor(&mut rng, 9, 16);
        let expect = functional::maxpool(&spec, &inp);

        let mut dev = StreamAccelerator::new(UsbLink::usb3_frontpanel());
        dev.load_commands(&[&spec]).unwrap();
        dev.load_layer().unwrap();
        let o = spec.o_side as usize;
        for g in 0..2 {
            for y in 0..o {
                let y0 = y * 2;
                let rows = 3.min(9 - y0);
                let slice = gemm::pool_slice(&inp, y0, rows, g);
                dev.load_data(&slice).unwrap();
                let task = SliceTask {
                    op: OpType::MaxPool,
                    k: 3,
                    stride: 2,
                    out_cols: o,
                    groups: 1,
                    oc_count: 8,
                    data_width: 9,
                    data_rows: rows,
                    pixel_mode: false,
                    kernel_size_reg: 9,
                    skip_relu: false,
                    weight_base: 0,
                    bias_base: 0,
                    pool_pad: 0,
                    data_base: 0,
                };
                let n = dev.restart_engine(&task).unwrap();
                let res = dev.read_results(n).unwrap();
                for x in 0..o {
                    for l in 0..8 {
                        assert_eq!(
                            res[x * 8 + l].to_bits(),
                            expect.get(y, x, g * 8 + l).to_bits(),
                            "g={g} y={y} x={x} l={l}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn resfifo_overflow_is_rejected() {
        let spec = LayerSpec::conv("t", 1, 1, 0, 200, 8, 8, 0);
        let mut dev = StreamAccelerator::new(UsbLink::usb3_frontpanel());
        dev.load_commands(&[&spec]).unwrap();
        dev.load_layer().unwrap();
        let task = SliceTask {
            op: OpType::ConvRelu,
            k: 1,
            stride: 1,
            out_cols: 200,
            groups: 1,
            oc_count: 8, // 1600 results > 1024
            data_width: 200,
            data_rows: 1,
            pixel_mode: false,
            kernel_size_reg: 1,
            skip_relu: false,
            weight_base: 0,
            bias_base: 0,
            pool_pad: 0,
            data_base: 0,
        };
        assert!(dev.restart_engine(&task).is_err());
    }

    #[test]
    fn command_shadow_replays_without_link_traffic() {
        let spec_a = LayerSpec::conv("a", 3, 2, 0, 227, 3, 64, 0);
        let spec_b = LayerSpec::maxpool("b", 3, 2, 113, 64);
        let mut dev = StreamAccelerator::new(UsbLink::usb3_frontpanel());

        dev.load_commands_cached("netA", &[&spec_a, &spec_b]).unwrap();
        let bytes_after_load = dev.usb.total_bytes();
        assert_eq!(dev.stats.command_loads, 1);
        // Drain like a forward would.
        assert_eq!(dev.csb.next_layer().unwrap().encode(), spec_a.encode());
        assert_eq!(dev.csb.next_layer().unwrap().encode(), spec_b.encode());

        // Same artifact key: replay from the shadow, zero new bytes.
        dev.load_commands_cached("netA", &[&spec_a, &spec_b]).unwrap();
        assert_eq!(dev.usb.total_bytes(), bytes_after_load);
        assert_eq!(dev.stats.command_loads, 1);
        assert_eq!(dev.stats.command_reuses, 1);
        assert_eq!(dev.csb.next_layer().unwrap().encode(), spec_a.encode());
        assert_eq!(dev.csb.next_layer().unwrap().encode(), spec_b.encode());

        // Different key: full reload over the link.
        dev.load_commands_cached("netB", &[&spec_b]).unwrap();
        assert!(dev.usb.total_bytes() > bytes_after_load);
        assert_eq!(dev.stats.command_loads, 2);
        // A keyless load invalidates the shadow entirely.
        dev.csb.next_layer();
        dev.load_commands(&[&spec_a]).unwrap();
        dev.csb.next_layer();
        dev.load_commands_cached("netB", &[&spec_b]).unwrap();
        assert_eq!(dev.stats.command_loads, 4);
        assert_eq!(dev.stats.command_reuses, 1);
    }

    #[test]
    fn weight_shadow_skips_resident_block_and_evicts_on_overlap() {
        let mut dev = StreamAccelerator::new(UsbLink::usb3_frontpanel());
        let wa: Vec<F16> = (0..64).map(F16::from_u32).collect();
        let ba: Vec<F16> = (0..4).map(F16::from_u32).collect();
        let wb: Vec<F16> = (100..164).map(F16::from_u32).collect();
        let bb: Vec<F16> = (100..104).map(F16::from_u32).collect();

        // Two keyed blocks at disjoint homes.
        assert!(!dev.load_weight_block_cached("art/L0#b0", 0, &wa, 0, &ba).unwrap());
        assert!(!dev.load_weight_block_cached("art/L1#b0", 8, &wb, 4, &bb).unwrap());
        assert_eq!(dev.stats.weight_loads, 2);
        let bytes = dev.usb.total_bytes();

        // Both still resident: replays cross zero bytes.
        assert!(dev.load_weight_block_cached("art/L0#b0", 0, &wa, 0, &ba).unwrap());
        assert!(dev.load_weight_block_cached("art/L1#b0", 8, &wb, 4, &bb).unwrap());
        assert_eq!(dev.usb.total_bytes(), bytes);
        assert_eq!(dev.stats.weight_loads, 2);
        assert_eq!(dev.stats.weight_reuses, 2);
        // The cache words really are the keyed block's values.
        assert_eq!(dev.weight_cache.read(8)[0].to_bits(), F16::from_u32(100).to_bits());

        // A keyless load over words [0, 8) evicts only the first block.
        dev.load_weights(&wa).unwrap();
        assert!(!dev.load_weight_block_cached("art/L0#b0", 0, &wa, 0, &ba).unwrap());
        assert!(dev.load_weight_block_cached("art/L1#b0", 8, &wb, 4, &bb).unwrap());

        // A different key at the same home is a miss, never an alias.
        assert!(!dev.load_weight_block_cached("other/L1#b0", 8, &wb, 4, &bb).unwrap());
        assert!(!dev.load_weight_block_cached("art/L1#b0", 8, &wb, 4, &bb).unwrap());
    }

    #[test]
    fn cache_overflow_is_rejected() {
        let mut dev = StreamAccelerator::new(UsbLink::usb3_frontpanel());
        let too_big = vec![F16::ZERO; DATA_CACHE_WORDS * 8 + 8];
        assert!(dev.load_data(&too_big).is_err());
    }

    #[test]
    fn data_base_sweeps_coalesced_slices() {
        let mut rng = Rng::new(0xC0A1);
        let spec = LayerSpec::conv("t", 3, 1, 0, 6, 8, 8, 0);
        let mut w = ConvWeights::zeros(8, 3, 8);
        for v in w.data.iter_mut() {
            *v = rng.normal(0.3);
        }
        for b in w.bias.iter_mut() {
            *b = rng.normal(0.1);
        }
        let wf = ConvWeightsF16::from_f32(&w);
        let imgs: Vec<TensorF16> = (0..3).map(|_| rand_tensor(&mut rng, 6, 8)).collect();
        let task = SliceTask {
            op: OpType::ConvRelu,
            k: 3,
            stride: 1,
            out_cols: 4,
            groups: 1,
            oc_count: 8,
            data_width: 6,
            data_rows: 3,
            pixel_mode: false,
            kernel_size_reg: 9,
            skip_relu: false,
            weight_base: 0,
            bias_base: 0,
            pool_pad: 0,
            data_base: 0,
        };

        // Reference: one device per image, slice loaded at word 0.
        let mut expect = Vec::new();
        for img in &imgs {
            let mut dev = StreamAccelerator::new(UsbLink::usb3_frontpanel());
            dev.load_commands(&[&spec]).unwrap();
            dev.load_layer().unwrap();
            dev.load_weights(&gemm::weight_block(&wf, 0, 8)).unwrap();
            dev.load_bias(&gemm::bias_block(&wf, 0, 8)).unwrap();
            dev.load_data(&gemm::conv_row_slice(img, 0, 3)).unwrap();
            let n = dev.restart_engine(&task).unwrap();
            expect.push(dev.read_results(n).unwrap());
        }

        // Coalesced: all three slices in one load, swept via data_base.
        let mut dev = StreamAccelerator::new(UsbLink::usb3_frontpanel());
        dev.load_commands(&[&spec]).unwrap();
        dev.load_layer().unwrap();
        dev.load_weights(&gemm::weight_block(&wf, 0, 8)).unwrap();
        dev.load_bias(&gemm::bias_block(&wf, 0, 8)).unwrap();
        let mut slab = Vec::new();
        for img in &imgs {
            slab.extend(gemm::conv_row_slice(img, 0, 3));
        }
        dev.load_data(&slab).unwrap();
        let words_per_img = 3 * 6 * 8 / 8;
        for (i, exp) in expect.iter().enumerate() {
            let t = SliceTask { data_base: i * words_per_img, ..task.clone() };
            let n = dev.restart_engine(&t).unwrap();
            let got = dev.read_results(n).unwrap();
            for (a, b) in got.iter().zip(exp) {
                assert_eq!(a.to_bits(), b.to_bits(), "img {i}");
            }
        }
        // One weight load swept by three conv passes.
        assert_eq!(dev.stats.weight_loads, 1);
        assert_eq!(dev.stats.weight_sweeps, 3);
        assert!(dev.stats.weight_reuse() > 2.9);

        // A slice based past the cache end is rejected, not wrapped.
        let bad = SliceTask { data_base: DATA_CACHE_WORDS, ..task };
        assert!(dev.restart_engine(&bad).is_err());
    }

    #[test]
    fn layer_tape_slices_per_layer_deltas() {
        let mut rng = Rng::new(0x7A9E);
        let spec = LayerSpec::conv("c1", 3, 1, 1, 6, 16, 8, 0);
        let mut w = ConvWeights::zeros(8, 3, 16);
        for v in w.data.iter_mut() {
            *v = rng.normal(0.3);
        }
        let wf = ConvWeightsF16::from_f32(&w);
        let raw = rand_tensor(&mut rng, 6, 16);
        let padded = raw.to_f32().pad_surface(1).to_f16();

        let mut dev = StreamAccelerator::new(UsbLink::usb3_frontpanel());
        dev.load_commands(&[&spec]).unwrap();
        // Disarmed by default: load_layer records nothing.
        dev.load_layer().unwrap();
        assert!(dev.take_layer_deltas().is_empty());

        // Armed: one mark per load_layer, deltas sliced at drain time.
        dev.load_commands(&[&spec]).unwrap();
        dev.begin_layer_tape();
        dev.load_layer().unwrap();
        let bytes_before = dev.usb.total_bytes();
        dev.load_weights(&gemm::weight_block(&wf, 0, 8)).unwrap();
        dev.load_bias(&gemm::bias_block(&wf, 0, 8)).unwrap();
        for y in 0..spec.o_side as usize {
            let slice = gemm::conv_row_slice(&padded, y * spec.stride as usize, 3);
            dev.load_data(&slice).unwrap();
            let task = SliceTask {
                op: OpType::ConvRelu,
                k: 3,
                stride: 1,
                out_cols: 6,
                groups: 2,
                oc_count: 8,
                data_width: 8,
                data_rows: 3,
                pixel_mode: false,
                kernel_size_reg: 9,
                skip_relu: false,
                weight_base: 0,
                bias_base: 0,
                pool_pad: 0,
                data_base: 0,
            };
            let n = dev.restart_engine(&task).unwrap();
            dev.read_results(n).unwrap();
        }
        let deltas = dev.take_layer_deltas();
        assert_eq!(deltas.len(), 1);
        let d = &deltas[0];
        assert_eq!(d.name, "c1");
        assert_eq!(d.passes, 6, "one pass per output row");
        assert_eq!(d.weight_loads, 1);
        assert!(d.cycles > 0);
        assert_eq!(d.link_bytes, dev.usb.total_bytes() - bytes_before);
        assert_eq!(d.resfifo_peak, 48, "each pass peaks at out_cols × oc before its drain");
        assert_eq!(d.data_peak_words, 48, "3 rows × 8 width × 2 groups");
        assert_eq!(d.weight_peak_words, 144, "8 oc × 9 taps × 2 groups");
        assert_eq!(d.stall_passes, 0);
        assert_eq!(d.epoch_reloads, 0, "commands were loaded before the layer window");
        // Drain disarms: the next forward records nothing until re-armed.
        dev.load_commands(&[&spec]).unwrap();
        dev.load_layer().unwrap();
        assert!(dev.take_layer_deltas().is_empty());
    }

    #[test]
    fn occupancy_watermarks_track_peaks_and_windows() {
        let mut rng = Rng::new(0xBEEF);
        let spec = LayerSpec::conv("t", 3, 1, 1, 6, 16, 8, 0);
        let mut w = ConvWeights::zeros(8, 3, 16);
        for v in w.data.iter_mut() {
            *v = rng.normal(0.3);
        }
        let wf = ConvWeightsF16::from_f32(&w);
        let raw = rand_tensor(&mut rng, 6, 16);
        let padded = raw.to_f32().pad_surface(1).to_f16();

        let mut dev = StreamAccelerator::new(UsbLink::usb3_frontpanel());
        assert_eq!(dev.watermarks(), Watermarks::default());
        dev.load_commands(&[&spec]).unwrap();
        assert_eq!(dev.watermarks().cmdfifo, 3, "one queued layer = 3 dwords");
        dev.load_layer().unwrap();
        dev.begin_occupancy_window();
        dev.load_weights(&gemm::weight_block(&wf, 0, 8)).unwrap();
        dev.load_bias(&gemm::bias_block(&wf, 0, 8)).unwrap();
        dev.load_data(&gemm::conv_row_slice(&padded, 0, 3)).unwrap();
        let task = SliceTask {
            op: OpType::ConvRelu,
            k: 3,
            stride: 1,
            out_cols: 6,
            groups: 2,
            oc_count: 8,
            data_width: 8,
            data_rows: 3,
            pixel_mode: false,
            kernel_size_reg: 9,
            skip_relu: false,
            weight_base: 0,
            bias_base: 0,
            pool_pad: 0,
            data_base: 0,
        };
        let n = dev.restart_engine(&task).unwrap();
        dev.read_results(n).unwrap();
        let wm = dev.occupancy_window();
        assert_eq!(wm.resfifo, 48, "one pass's results peak before the drain");
        assert_eq!(wm.data_words, 48);
        assert_eq!(wm.weight_words, 144);
        assert_eq!(wm.cmdfifo, 0, "commands were loaded before this window opened");
        // Resetting the window leaves the device-lifetime peaks intact.
        dev.begin_occupancy_window();
        assert_eq!(dev.occupancy_window(), Watermarks::default());
        assert_eq!(dev.watermarks().resfifo, 48);
        assert_eq!(dev.watermarks().cmdfifo, 3);
    }
}
