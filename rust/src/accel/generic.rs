//! The *generic* (DRAM-based) accelerator baseline — Figs 14–16, the
//! architecture the paper evaluated and rejected in §3.4.2.
//!
//! Input image, weights and parameters are loaded into off-chip DDR2
//! once; DMAs then move data between DRAM and the on-chip caches through
//! the Spartan-6 MCB (22–32-cycle read latency, [`crate::hw::mcb`]).
//! im2col's scattered window reads become many short bursts — each paying
//! the full MCB latency — and write-back needs jump addressing to leave
//! room for the next layer's padding (Fig 16) plus NHWC→NWHC reshaping
//! for concat layers. This model quantifies exactly those costs so the
//! A3 ablation can reproduce the paper's architecture choice.

use crate::perfmodel::layer_engine_cycles;
use crate::hw::clock::ClockDomain;
use crate::hw::mcb::{McbConfig, McbPort};
use crate::hw::usb::{Endpoint, UsbLink, UsbPort};
use crate::net::graph::Network;
use crate::net::layer::{LayerSpec, OpType};

/// Per-layer cost report for the generic architecture.
#[derive(Clone, Debug)]
pub struct GenericLayerReport {
    pub name: String,
    /// DRAM-domain cycles spent on DMA reads (data + weights).
    pub dram_read_cycles: u64,
    /// DRAM-domain cycles spent on result write-back (incl. padding
    /// jump-addressing overhead).
    pub dram_write_cycles: u64,
    /// Engine-domain compute cycles (same engine as the stream design).
    pub engine_cycles: u64,
    /// DMA transactions issued (each pays MCB latency).
    pub dma_txns: u64,
    /// Layer wall time: DMA and compute do NOT overlap in the Fig 15
    /// flow (read → compute → write-back, per piece).
    pub seconds: f64,
}

/// Whole-network cost report.
#[derive(Clone, Debug)]
pub struct GenericReport {
    pub layers: Vec<GenericLayerReport>,
    /// One-time USB load of image + all weights into DRAM.
    pub initial_load_seconds: f64,
    /// Final result readback.
    pub readback_seconds: f64,
}

impl GenericReport {
    pub fn total_seconds(&self) -> f64 {
        self.initial_load_seconds
            + self.readback_seconds
            + self.layers.iter().map(|l| l.seconds).sum::<f64>()
    }

    pub fn total_dma_txns(&self) -> u64 {
        self.layers.iter().map(|l| l.dma_txns).sum()
    }

    pub fn total_engine_seconds(&self) -> f64 {
        self.layers.iter().map(|l| ClockDomain::ENGINE.secs(l.engine_cycles)).sum()
    }

    pub fn total_dram_seconds(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| ClockDomain::DRAM.secs(l.dram_read_cycles + l.dram_write_cycles))
            .sum()
    }
}

/// Model one layer on the generic architecture.
///
/// Access pattern per §3.4.2's discussion of im2col over DRAM:
/// * data: for every output pixel and window row, one DMA burst of
///   `k · lanes` FP16 values (contiguous in NHWC), then a jump
///   (`BURST_LEN · (input_side − kernel)` addressing, Fig 16) — the jump
///   forces a new transaction, which is the point;
/// * weights: one burst per output-channel block per output row (weights
///   for the current 8 output channels stream once per row-piece);
/// * write-back: one burst per output row per channel group, plus a jump
///   transaction reserving the next layer's padding rows (Fig 16).
pub fn simulate_layer(spec: &LayerSpec, cfg: McbConfig) -> GenericLayerReport {
    let k = spec.kernel as u64;
    let o = spec.o_side as u64;
    let lanes = (spec.i_ch as u64).div_ceil(8) * 8;
    let mut port = McbPort::new(cfg);

    match spec.op {
        OpType::ConvRelu => {
            // Data: o² pixels × k window rows, each a burst of k·lanes
            // values = k·lanes/2 32-bit words.
            let burst_words = ((k * lanes) / 2).max(1) as u32;
            for _ in 0..(o * o * k) {
                port.read_burst(burst_words);
            }
            // Weights: per output row, per oc-block of 8: k²·lanes·8/2 words.
            let oc_blocks = (spec.o_ch as u64).div_ceil(8);
            let w_words = ((k * k * lanes * 8) / 2).max(1) as u32;
            for _ in 0..(o * oc_blocks) {
                port.read_burst(w_words);
            }
            let read_cycles = port.cycles;
            // Write-back: o rows × oc-blocks, one burst each of o·8/2
            // words + a jump transaction for padding rows (Fig 16).
            let wb_words = ((o * 8) / 2).max(1) as u32;
            for _ in 0..(o * oc_blocks) {
                port.write_burst(wb_words);
                if spec.padding > 0 {
                    port.write_burst(((2 * spec.padding as u64 * 8) / 2).max(1) as u32);
                }
            }
            finish(spec, port, read_cycles)
        }
        OpType::MaxPool | OpType::AvgPool => {
            let groups = (spec.i_ch as u64).div_ceil(8);
            let burst_words = ((k * 8) / 2).max(1) as u32;
            for _ in 0..(o * o * k * groups) {
                port.read_burst(burst_words);
            }
            let read_cycles = port.cycles;
            let wb_words = ((o * 8) / 2).max(1) as u32;
            for _ in 0..(o * groups) {
                port.write_burst(wb_words);
            }
            finish(spec, port, read_cycles)
        }
        OpType::Idle => GenericLayerReport {
            name: spec.name.clone(),
            dram_read_cycles: 0,
            dram_write_cycles: 0,
            engine_cycles: 0,
            dma_txns: 0,
            seconds: 0.0,
        },
    }
}

fn finish(spec: &LayerSpec, port: McbPort, read_cycles: u64) -> GenericLayerReport {
    let engine_cycles = layer_engine_cycles(spec, 8);
    let dram_write_cycles = port.cycles - read_cycles;
    let seconds = ClockDomain::DRAM.secs(port.cycles) + ClockDomain::ENGINE.secs(engine_cycles);
    GenericLayerReport {
        name: spec.name.clone(),
        dram_read_cycles: read_cycles,
        dram_write_cycles,
        engine_cycles,
        dma_txns: port.txns,
        seconds,
    }
}

/// Model a whole network on the generic architecture.
pub fn simulate_network(net: &Network, cfg: McbConfig, link: UsbLink) -> GenericReport {
    let mut usb = UsbPort::new(link);
    // Initial load: image + every weight, in 512-DWORD blocks (Fig 15) —
    // large blocks amortize the per-transaction latency well.
    let image_bytes = 227u64 * 227 * 8 * 2;
    let weight_bytes = net.total_weights() * 2;
    let block = 512 * 4u64;
    let total = image_bytes + weight_bytes;
    for _ in 0..total.div_ceil(block) {
        usb.transfer(Endpoint::PipeIn, block);
    }
    let initial_load_seconds = usb.total_seconds();

    let layers: Vec<GenericLayerReport> =
        net.engine_layers().iter().map(|s| simulate_layer(s, cfg)).collect();

    let (_, out_ch) = net.out_shape(net.nodes.len() - 1);
    let readback_seconds = link.txn_time(out_ch as u64 * 4);

    GenericReport { layers, initial_load_seconds, readback_seconds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::squeezenet::squeezenet_v11;

    #[test]
    fn scattered_reads_dominate_generic_conv() {
        let spec = LayerSpec::conv("conv1", 3, 2, 0, 227, 3, 64, 0);
        let r = simulate_layer(&spec, McbConfig::default());
        // 113²×3 data bursts plus weight bursts — tens of thousands of
        // transactions, each paying ~27 cycles of MCB latency.
        assert!(r.dma_txns > 38_000, "{}", r.dma_txns);
        assert!(r.dram_read_cycles > r.dram_write_cycles);
        assert!(r.seconds > 0.0);
    }

    #[test]
    fn generic_whole_network_report() {
        let net = squeezenet_v11();
        let rep = simulate_network(&net, McbConfig::default(), UsbLink::usb3_frontpanel());
        assert_eq!(rep.layers.len(), 30);
        // Initial load moves ~2.5 MB of weights in 2 KB blocks; with the
        // calibrated 1 ms/txn FrontPanel overhead that is a ~1.5 s, one
        // time cost.
        assert!(rep.initial_load_seconds < 3.0, "{}", rep.initial_load_seconds);
        assert!(rep.total_seconds() > rep.initial_load_seconds);
        assert!(rep.total_dma_txns() > 400_000, "{}", rep.total_dma_txns());
    }

    #[test]
    fn padding_adds_writeback_jumps() {
        let no_pad = simulate_layer(&LayerSpec::conv("a", 3, 1, 0, 28, 64, 64, 0), McbConfig::default());
        let pad = simulate_layer(&LayerSpec::conv("b", 3, 1, 1, 26, 64, 64, 0), McbConfig::default());
        // Same output side (26+2-3+1 = 26 vs 28-3+1 = 26): padding costs
        // extra write transactions.
        assert!(pad.dram_write_cycles > no_pad.dram_write_cycles);
    }

    #[test]
    fn higher_mcb_latency_hurts_proportionally() {
        let spec = LayerSpec::conv("c", 3, 1, 1, 28, 64, 64, 0);
        let fast = simulate_layer(&spec, McbConfig { read_latency: 22, ..Default::default() });
        let slow = simulate_layer(&spec, McbConfig { read_latency: 32, ..Default::default() });
        assert!(slow.dram_read_cycles > fast.dram_read_cycles);
        assert_eq!(slow.dma_txns, fast.dma_txns);
    }
}
