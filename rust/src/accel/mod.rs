//! Accelerator top-levels: the shipped stream architecture (Fig 22) and
//! the generic DRAM-based architecture (Fig 14) it was chosen over.

pub mod generic;
pub mod stream;

pub use stream::{SliceTask, StreamAccelerator};
