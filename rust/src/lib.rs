//! # FusionAccel
//!
//! A full-system reproduction of *"FusionAccel: A General Re-configurable
//! Deep Learning Inference Accelerator on FPGA for Convolutional Neural
//! Networks"* (Shi Shi, 2019) as a three-layer Rust + JAX + Pallas stack.
//!
//! * **L3 (this crate)** — the PC-host driver software (paper Fig 36), a
//!   functional + cycle-level simulator of the RTL accelerator (Figs
//!   22–27, 31–35), and a multi-device inference coordinator.
//! * **L2 (python/compile/model.py)** — SqueezeNet v1.1 / AlexNet in JAX,
//!   AOT-lowered to HLO text and executed from [`runtime`] via PJRT as
//!   the FP32 "Caffe-CPU" oracle.
//! * **L1 (python/compile/kernels/)** — Pallas im2col+GEMM convolution
//!   and pooling kernels (interpret mode), validated against `ref.py`.
//!
//! See DESIGN.md for the system inventory and the per-experiment index,
//! and EXPERIMENTS.md for measured results.
//!
//! ## Public facade
//!
//! The serving stack reads top-down; the curated re-exports below are
//! the intended entry points, so most callers never spell out module
//! paths:
//!
//! 1. **Describe & compile** — build a [`net::graph::Network`], attach
//!    weights, and register it in a [`ModelRepo`] (which runs
//!    [`compile`] and pins the [`CompiledStream`] artifact, including
//!    its oracle-modeled cost, [`StreamCost`]).
//! 2. **Serve** — start a long-lived [`Service`] over the repo
//!    ([`ServiceConfig`] / [`ServeConfig`], builder-style `with_*`
//!    tunables throughout), or run a closed batch with
//!    [`Service::run_closed`].
//! 3. **Expose** — put a [`FrontDoor`] (TCP line protocol,
//!    [`DoorConfig`]) in front; talk to it with [`Client`].
//! 4. **Observe** — scrape [`Service::live_stats`]
//!    ([`ServiceSnapshot`]), per-layer measured counters
//!    ([`telemetry::LayerFamily`]), or request-lifecycle traces
//!    ([`telemetry::Hub`]).

pub mod accel;
pub mod algos;
pub mod benchkit;
pub mod compiler;
pub mod coordinator;
pub mod engine;
pub mod fp16;
pub mod frontdoor;
pub mod host;
pub mod hw;
pub mod net;
pub mod perfmodel;
pub mod prop;
pub mod resources;
pub mod runtime;
pub mod service;
pub mod telemetry;

pub use compiler::{compile, CompiledStream, LayerCost, ModelRepo, Residency, StreamCost};
pub use coordinator::{
    BatchPolicy, InferenceRequest, InferenceResponse, ServeConfig, ServeStats,
};
pub use frontdoor::client::Client;
pub use frontdoor::{DoorConfig, DoorStats, FrontDoor};
pub use service::{ClosedReport, Service, ServiceConfig, SubmitError, Ticket};
pub use telemetry::{NetworkSnapshot, ServiceSnapshot, WorkerSnapshot};
