//! # FusionAccel
//!
//! A full-system reproduction of *"FusionAccel: A General Re-configurable
//! Deep Learning Inference Accelerator on FPGA for Convolutional Neural
//! Networks"* (Shi Shi, 2019) as a three-layer Rust + JAX + Pallas stack.
//!
//! * **L3 (this crate)** — the PC-host driver software (paper Fig 36), a
//!   functional + cycle-level simulator of the RTL accelerator (Figs
//!   22–27, 31–35), and a multi-device inference coordinator.
//! * **L2 (python/compile/model.py)** — SqueezeNet v1.1 / AlexNet in JAX,
//!   AOT-lowered to HLO text and executed from [`runtime`] via PJRT as
//!   the FP32 "Caffe-CPU" oracle.
//! * **L1 (python/compile/kernels/)** — Pallas im2col+GEMM convolution
//!   and pooling kernels (interpret mode), validated against `ref.py`.
//!
//! See DESIGN.md for the system inventory and the per-experiment index,
//! and EXPERIMENTS.md for measured results.

pub mod accel;
pub mod algos;
pub mod benchkit;
pub mod compiler;
pub mod coordinator;
pub mod engine;
pub mod fp16;
pub mod frontdoor;
pub mod host;
pub mod hw;
pub mod net;
pub mod perfmodel;
pub mod prop;
pub mod resources;
pub mod runtime;
pub mod service;
pub mod telemetry;
