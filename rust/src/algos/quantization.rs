//! INT8 post-training quantization — the §4 precision trade-off made
//! measurable.
//!
//! The paper picks FP16 because "FP16 models do not have to be quantized
//! and retrained from FP32 like INT8" while "saving 50 % storage …
//! compared to FP32". This module implements the road not taken: a
//! CHaiDNN-style symmetric per-tensor INT8 conv path (i32 accumulators,
//! requantize at the output) with *post-training* scales — no
//! retraining, exactly the scenario the paper avoids — so the A4 bench
//! can quantify the accuracy gap that justifies the FP16 choice.

use crate::net::tensor::{ConvWeights, Tensor, TensorF32};

/// Symmetric per-tensor scale: real ≈ q · scale, q ∈ [-127, 127].
#[derive(Clone, Copy, Debug)]
pub struct Qscale(pub f32);

impl Qscale {
    /// Calibrate from the max-abs of a tensor (the simplest PTQ rule).
    pub fn calibrate(data: &[f32]) -> Qscale {
        let m = data.iter().fold(0f32, |a, &v| a.max(v.abs()));
        Qscale(if m > 0.0 { m / 127.0 } else { 1.0 })
    }

    #[inline]
    pub fn quantize(&self, v: f32) -> i8 {
        (v / self.0).round().clamp(-127.0, 127.0) as i8
    }

    #[inline]
    pub fn dequantize(&self, q: i8) -> f32 {
        q as f32 * self.0
    }
}

/// Quantize a whole tensor, returning (values, scale).
pub fn quantize_tensor(data: &[f32]) -> (Vec<i8>, Qscale) {
    let s = Qscale::calibrate(data);
    (data.iter().map(|&v| s.quantize(v)).collect(), s)
}

/// INT8 convolution + ReLU with i32 accumulation and float requantization
/// (bias added in float, as accelerators with float bias units do).
/// Activations are (re)quantized per layer — the error source the paper
/// avoids by using FP16 directly.
pub fn conv_int8(
    input: &TensorF32,
    w: &ConvWeights,
    stride: usize,
    pad: usize,
    relu: bool,
) -> TensorF32 {
    let k = w.k;
    let padded = input.pad_surface(pad);
    let o = (padded.h - k) / stride + 1;
    let (qx, sx) = quantize_tensor(&padded.data);
    let (qw, sw) = quantize_tensor(&w.data);
    let out_scale = sx.0 * sw.0;

    let mut out = Tensor::zeros(o, o, w.o_ch);
    for oc in 0..w.o_ch {
        for y in 0..o {
            for x in 0..o {
                let mut acc: i32 = 0;
                for ky in 0..k {
                    for kx in 0..k {
                        for c in 0..w.i_ch {
                            let xi = qx[(((y * stride + ky) * padded.w) + x * stride + kx)
                                * padded.c
                                + c] as i32;
                            let wi = qw[w.idx(oc, ky, kx, c)] as i32;
                            acc += xi * wi;
                        }
                    }
                }
                let mut v = acc as f32 * out_scale + w.bias[oc];
                if relu {
                    v = v.max(0.0);
                }
                out.set(y, x, oc, v);
            }
        }
    }
    out
}

/// Accuracy summary of a quantized layer vs its FP32 reference.
#[derive(Clone, Copy, Debug)]
pub struct QuantReport {
    pub max_abs: f32,
    pub mean_abs: f32,
    /// Signal-to-quantization-noise ratio in dB.
    pub sqnr_db: f32,
}

pub fn compare(got: &TensorF32, reference: &TensorF32) -> QuantReport {
    assert_eq!(got.data.len(), reference.data.len());
    let mut max_abs = 0f32;
    let mut sum = 0f64;
    let mut sig = 0f64;
    let mut noise = 0f64;
    for (a, b) in got.data.iter().zip(&reference.data) {
        let d = (a - b).abs();
        max_abs = max_abs.max(d);
        sum += d as f64;
        sig += (*b as f64) * (*b as f64);
        noise += (d as f64) * (d as f64);
    }
    QuantReport {
        max_abs,
        mean_abs: (sum / got.data.len() as f64) as f32,
        sqnr_db: (10.0 * (sig / noise.max(1e-30)).log10()) as f32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::functional::{conv as conv_f16, ConvWeightsF16};
    use crate::net::layer::LayerSpec;
    use crate::prop::Rng;

    fn case(rng: &mut Rng, side: usize, c: usize, oc: usize, k: usize) -> (TensorF32, ConvWeights) {
        let input =
            Tensor::from_vec(side, side, c, (0..side * side * c).map(|_| rng.normal(1.0)).collect());
        let mut w = ConvWeights::zeros(oc, k, c);
        for v in w.data.iter_mut() {
            *v = rng.normal(0.3);
        }
        for b in w.bias.iter_mut() {
            *b = rng.normal(0.1);
        }
        (input, w)
    }

    #[test]
    fn quantize_roundtrip_bounds() {
        let s = Qscale::calibrate(&[-2.0, 1.0, 0.5]);
        assert!((s.dequantize(s.quantize(1.0)) - 1.0).abs() < 2.0 / 127.0);
        assert_eq!(s.quantize(100.0), 127); // clamps
        assert_eq!(s.quantize(-100.0), -127);
    }

    #[test]
    fn int8_tracks_f32_but_coarser_than_f16() {
        let mut rng = Rng::new(0x18);
        let (input, w) = case(&mut rng, 10, 16, 8, 3);
        let (f32_ref, _) = crate::algos::convolution::im2col_gemm(&input, &w, 1, 1);
        let f32_relu = TensorF32 {
            h: f32_ref.h,
            w: f32_ref.w,
            c: f32_ref.c,
            data: f32_ref.data.iter().map(|v| v.max(0.0)).collect(),
        };

        let q = conv_int8(&input, &w, 1, 1, true);
        let rq = compare(&q, &f32_relu);

        let spec = LayerSpec::conv("t", 3, 1, 1, 10, 16, 8, 0);
        let wf = ConvWeightsF16::from_f32(&w);
        let h = conv_f16(&spec, &input.pad_surface(1).to_f16(), &wf).to_f32();
        let rh = compare(&h, &f32_relu);

        // INT8 must still correlate (SQNR > 20 dB on one layer) …
        assert!(rq.sqnr_db > 20.0, "int8 sqnr {}", rq.sqnr_db);
        // … but FP16 is far more accurate without any calibration —
        // the §4 design rationale.
        assert!(rh.sqnr_db > rq.sqnr_db + 15.0, "f16 {} vs int8 {}", rh.sqnr_db, rq.sqnr_db);
    }

    #[test]
    fn int8_zero_input_is_exact() {
        let mut rng = Rng::new(1);
        let (_, w) = case(&mut rng, 4, 4, 2, 1);
        let input = Tensor::zeros(4, 4, 4);
        let out = conv_int8(&input, &w, 1, 0, false);
        for oc in 0..2 {
            assert!((out.get(0, 0, oc) - w.bias[oc]).abs() < 1e-6);
        }
    }
}
