//! im2col+GEMM vs MEC convolution (§3.3.1, §3.3.2, §3.4.3) — functional
//! implementations with memory-access counters, so the A2 ablation can
//! reproduce the paper's trade-off: MEC reads each input element once
//! (surface-first parallelism) at the cost of stride-dependent slot
//! logic and kernel-proportional hardware; im2col re-reads overlapped
//! window data but keeps the control logic uniform (channel-first).

use crate::net::tensor::{ConvWeights, Tensor, TensorF32};

/// Access statistics of one convolution run.
#[derive(Clone, Copy, Debug, Default)]
pub struct ConvAccessReport {
    /// Scalar reads from the input activation memory.
    pub input_reads: u64,
    /// Scalar reads from the weight memory.
    pub weight_reads: u64,
    /// Multiply-accumulates.
    pub macs: u64,
    /// Peak parallel multiplier slots in use (MEC's varying parallelism
    /// vs im2col's constant lanes).
    pub peak_parallelism: u32,
    /// Minimum parallel slots in use over steady state.
    pub min_parallelism: u32,
}

/// Plain im2col + GEMM convolution (f32 reference semantics): builds the
/// lowered matrix explicitly (every window element copied once per use,
/// §3.3.1) and multiplies.
pub fn im2col_gemm(
    input: &TensorF32,
    w: &ConvWeights,
    stride: usize,
    pad: usize,
) -> (TensorF32, ConvAccessReport) {
    let k = w.k;
    let padded = input.pad_surface(pad);
    let o = (padded.h - k) / stride + 1;
    let cols = k * k * input.c;
    let mut rep = ConvAccessReport {
        peak_parallelism: 8,
        min_parallelism: 8,
        ..Default::default()
    };

    // im2col: (o*o) × (k*k*c) matrix — each element is one input read.
    let mut lowered = vec![0f32; o * o * cols];
    for y in 0..o {
        for x in 0..o {
            let mut col = 0;
            for ky in 0..k {
                for kx in 0..k {
                    for c in 0..input.c {
                        lowered[(y * o + x) * cols + col] = padded.get(y * stride + ky, x * stride + kx, c);
                        rep.input_reads += 1;
                        col += 1;
                    }
                }
            }
        }
    }
    // GEMM: [o², cols] × [cols, o_ch].
    let mut out = Tensor::zeros(o, o, w.o_ch);
    for y in 0..o {
        for x in 0..o {
            for oc in 0..w.o_ch {
                let mut acc = w.bias[oc];
                for ky in 0..k {
                    for kx in 0..k {
                        for c in 0..input.c {
                            let col = (ky * k + kx) * input.c + c;
                            acc += lowered[(y * o + x) * cols + col] * w.get(oc, ky, kx, c);
                            rep.weight_reads += 1;
                            rep.macs += 1;
                        }
                    }
                }
                out.set(y, x, oc, acc);
            }
        }
    }
    (out, rep)
}

/// MEC convolution (§3.3.2, Figs 11/19/20): slide the kernel down one
/// *column strip* of the input; each strip element is read once and
/// shared by the (k − stride + 1 …) overlapping windows via parallel
/// slots. Functionally identical to im2col; the access counts differ.
pub fn mec(
    input: &TensorF32,
    w: &ConvWeights,
    stride: usize,
    pad: usize,
) -> (TensorF32, ConvAccessReport) {
    let k = w.k;
    let padded = input.pad_surface(pad);
    let o = (padded.h - k) / stride + 1;
    let mut rep = ConvAccessReport { min_parallelism: u32::MAX, ..Default::default() };

    let mut out = Tensor::zeros(o, o, w.o_ch);
    // Partial sums per (output row within strip, output channel).
    // Process one output column x at a time: read the k input columns
    // x·s .. x·s+k once ("sequentially reads out input_side · kernel
    // data"), and accumulate into all o output rows in pipeline.
    for x in 0..o {
        // acc[y][oc]
        let mut acc: Vec<Vec<f32>> = vec![w.bias.clone(); o];
        for iy in 0..padded.h {
            // Which output rows' windows cover input row iy?
            // y·s ≤ iy < y·s + k.
            let y_hi = iy / stride;
            let y_lo = iy.saturating_sub(k - 1).div_ceil(stride);
            let mut active = 0u32;
            for kx in 0..k {
                for c in 0..input.c {
                    let v = padded.get(iy, x * stride + kx, c);
                    rep.input_reads += 1;
                    for y in y_lo..=y_hi.min(o - 1) {
                        let ky = iy - y * stride;
                        active = active.max((y_hi.min(o - 1) - y_lo + 1) as u32);
                        for oc in 0..w.o_ch {
                            acc[y][oc] += v * w.get(oc, ky, kx, c);
                            rep.weight_reads += 1;
                            rep.macs += 1;
                        }
                    }
                }
            }
            if active > 0 {
                rep.peak_parallelism = rep.peak_parallelism.max(active);
                rep.min_parallelism = rep.min_parallelism.min(active);
            }
        }
        for y in 0..o {
            for oc in 0..w.o_ch {
                out.set(y, x, oc, acc[y][oc]);
            }
        }
    }
    if rep.min_parallelism == u32::MAX {
        rep.min_parallelism = 0;
    }
    (out, rep)
}

/// Number of parallel computation slots surface-first parallelism needs
/// (§3.4.3): `kernel − stride + 1` groups; a slot is idle when
/// stride ≥ 2 ("there is a slot that is always empty").
pub fn mec_slots(kernel: usize, stride: usize) -> (usize, usize) {
    let total = kernel;
    let used = kernel.saturating_sub(stride) + 1;
    (total, used.min(total))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::Rng;

    fn rand_case(rng: &mut Rng, side: usize, c: usize, oc: usize, k: usize) -> (TensorF32, ConvWeights) {
        let input = Tensor::from_vec(side, side, c, (0..side * side * c).map(|_| rng.normal(1.0)).collect());
        let mut w = ConvWeights::zeros(oc, k, c);
        for v in w.data.iter_mut() {
            *v = rng.normal(0.3);
        }
        for b in w.bias.iter_mut() {
            *b = rng.normal(0.1);
        }
        (input, w)
    }

    #[test]
    fn mec_matches_im2col_functionally() {
        let mut rng = Rng::new(0x3EC);
        for (k, s, pad) in [(3usize, 1usize, 0usize), (3, 1, 1), (3, 2, 0), (1, 1, 0), (5, 2, 2)] {
            let (input, w) = rand_case(&mut rng, 9, 4, 3, k);
            let (a, _) = im2col_gemm(&input, &w, s, pad);
            let (b, _) = mec(&input, &w, s, pad);
            assert_eq!(a.h, b.h);
            for (x, y) in a.data.iter().zip(&b.data) {
                assert!((x - y).abs() < 1e-3, "k={k} s={s}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn mec_reads_each_input_once_im2col_rereads() {
        let mut rng = Rng::new(7);
        let (input, w) = rand_case(&mut rng, 9, 4, 2, 3);
        let (_, rep_i) = im2col_gemm(&input, &w, 1, 0);
        let (_, rep_m) = mec(&input, &w, 1, 0);
        let input_elems = (9 * 9 * 4) as u64;
        // im2col reads ≈ k² copies of interior elements.
        assert!(rep_i.input_reads > 5 * input_elems, "{}", rep_i.input_reads);
        // MEC reads each strip element once per output column: ≤ k× total
        // (columns overlap by k−s), far fewer than im2col.
        assert!(rep_m.input_reads < rep_i.input_reads / 2);
        assert_eq!(rep_i.macs, rep_m.macs);
    }

    #[test]
    fn mec_parallelism_varies_im2col_constant() {
        let mut rng = Rng::new(8);
        let (input, w) = rand_case(&mut rng, 9, 4, 2, 3);
        let (_, rep_i) = im2col_gemm(&input, &w, 1, 0);
        let (_, rep_m) = mec(&input, &w, 1, 0);
        assert_eq!(rep_i.peak_parallelism, rep_i.min_parallelism);
        // MEC ramps up at strip edges (§3.4.3: "the parallel computation
        // units are not all activated" at start).
        assert!(rep_m.peak_parallelism > rep_m.min_parallelism);
    }

    #[test]
    fn slot_occupancy_matches_paper() {
        // k=3, s=1: all 3 slots occupied (sum_enable = 111, Fig 19).
        assert_eq!(mec_slots(3, 1), (3, 3));
        // k=3, s=2: one slot always empty (Fig 20).
        assert_eq!(mec_slots(3, 2), (3, 2));
        // k=11 (AlexNet): slot count grows with the kernel — the §3.4.3
        // scalability objection.
        assert_eq!(mec_slots(11, 1).0, 11);
    }
}
