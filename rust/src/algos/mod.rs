//! The §3.3 "optional optimization algorithms" — implemented so the
//! paper's §3.4 trade-off decisions can be measured, not just asserted:
//! bitonic sorting networks, pipeline accumulation, and MEC vs
//! im2col+GEMM convolution with access counters.

pub mod bitonic;
pub mod convolution;
pub mod pipeline_accum;
pub mod quantization;

pub use bitonic::{bitonic_max, bitonic_sort, sequential_max, SortReport};
pub use convolution::{im2col_gemm, mec, mec_slots, ConvAccessReport};
pub use pipeline_accum::{pipeline_accumulate, sequential_accumulate, AccumReport};
pub use quantization::{compare as quant_compare, conv_int8, quantize_tensor, QuantReport};
