//! Pipeline accumulation (§3.3.4, Fig 13): summing an array with a fixed
//! pool of adders, trading time for space. The paper's Fig 13 example —
//! 32 adders summing 13×13 = 169 numbers — reads 64, 32, 32, 32, 4, 2,
//! 2, 0, 0, 1 values over 10 cycles; the irregular readout is one of the
//! reasons the algorithm was rejected (§3.4.1).

use crate::fp16::F16;

/// Cycle-by-cycle trace of a pipeline accumulation.
#[derive(Clone, Debug, Default)]
pub struct AccumReport {
    /// Values read from memory each cycle (the §3.3.4 irregularity).
    pub reads_per_cycle: Vec<u64>,
    /// Adders active each cycle.
    pub active_adders: Vec<u64>,
    /// Total cycles.
    pub cycles: u32,
    /// Mean adder utilization over the run (≤ 1; the paper notes it is
    /// "always a moment that the computation utilization ratio is less or
    /// significantly less than 100%").
    pub utilization: f64,
}

/// Sum `values` with `adders` parallel FP16 adders, Fig 13 style:
/// each cycle every adder can combine two operands drawn from (pending
/// inputs ++ partial sums from previous cycles). Returns (sum, report).
pub fn pipeline_accumulate(values: &[F16], adders: usize) -> (F16, AccumReport) {
    assert!(adders > 0);
    let mut rep = AccumReport::default();
    if values.is_empty() {
        return (F16::ZERO, rep);
    }
    let mut pending: std::collections::VecDeque<F16> = values.iter().copied().collect();
    let mut partials: Vec<F16> = Vec::new();
    let total_inputs = values.len();
    let mut reads_done = 0usize;

    while pending.len() + partials.len() > 1 {
        // Operand pool this cycle: partial sums first (they are registered
        // on-chip), then as many fresh reads as adders still need.
        let mut pool: Vec<F16> = std::mem::take(&mut partials);
        let mut reads = 0u64;
        while pool.len() < 2 * adders && !pending.is_empty() {
            pool.push(pending.pop_front().unwrap());
            reads += 1;
        }
        let pairs = pool.len() / 2;
        let mut next: Vec<F16> = Vec::with_capacity(pairs + 1);
        for i in 0..pairs {
            next.push(pool[2 * i].add(pool[2 * i + 1]));
        }
        if pool.len() % 2 == 1 {
            next.push(pool[pool.len() - 1]);
        }
        reads_done += reads as usize;
        rep.reads_per_cycle.push(reads);
        rep.active_adders.push(pairs as u64);
        rep.cycles += 1;
        partials = next;
        assert!(rep.cycles < 10_000, "accumulation did not converge");
    }
    // A single remaining input never enters the adder array — it passes
    // straight through below.
    debug_assert_eq!(reads_done + pending.len(), total_inputs);
    let sum = partials.first().copied().or_else(|| pending.pop_front()).unwrap_or(F16::ZERO);
    let used: u64 = rep.active_adders.iter().sum();
    rep.utilization = used as f64 / (rep.cycles as u64 * adders as u64) as f64;
    (sum, rep)
}

/// The RTL's actual approach (Fig 27): one accumulator per lane adding
/// sequentially at II=2. Returns (sum, cycles).
pub fn sequential_accumulate(values: &[F16]) -> (F16, u32) {
    let mut acc = F16::ZERO;
    for &v in values {
        acc = acc.add(v);
    }
    (acc, 2 * values.len() as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{forall, Rng};

    #[test]
    fn fig13_geometry_169_values_32_adders() {
        let values: Vec<F16> = (0..169).map(|_| F16::ONE).collect();
        let (sum, rep) = pipeline_accumulate(&values, 32);
        assert_eq!(sum.to_f32(), 169.0); // exact in FP16
        // First cycle reads 2·32 = 64 fresh values, as in Fig 13.
        assert_eq!(rep.reads_per_cycle[0], 64);
        // Reads must total 169 and taper off irregularly.
        assert_eq!(rep.reads_per_cycle.iter().sum::<u64>(), 169);
        assert!(rep.cycles <= 12, "{}", rep.cycles);
        // Utilization strictly below 100% (the §3.3.4 drawback).
        assert!(rep.utilization < 1.0);
    }

    #[test]
    fn fewer_adders_cost_more_cycles() {
        let values: Vec<F16> = (0..169).map(|_| F16::ONE).collect();
        let (_, r32) = pipeline_accumulate(&values, 32);
        let (_, r8) = pipeline_accumulate(&values, 8);
        let (_, r1) = pipeline_accumulate(&values, 1);
        assert!(r8.cycles > r32.cycles);
        assert!(r1.cycles > r8.cycles);
        assert_eq!(r1.cycles, 168); // one add per cycle, n-1 adds
    }

    #[test]
    fn tree_sum_exact_for_exact_inputs() {
        // Integer-valued FP16 inputs small enough that every partial sum
        // is exact — pipeline and sequential must agree exactly.
        forall(
            0xACC,
            300,
            |r: &mut Rng| {
                let n = r.below(200) + 1;
                (0..n).map(|_| F16::from_u32(r.below(8) as u32)).collect::<Vec<_>>()
            },
            |xs| {
                let (a, _) = pipeline_accumulate(xs, 16);
                let (b, _) = sequential_accumulate(xs);
                if a.to_bits() == b.to_bits() {
                    Ok(())
                } else {
                    Err(format!("{a:?} vs {b:?}"))
                }
            },
        );
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(pipeline_accumulate(&[], 4).0.to_bits(), 0);
        let one = [F16::from_f32(2.5)];
        assert_eq!(pipeline_accumulate(&one, 4).0.to_f32(), 2.5);
    }
}
