//! Bitonic sort (§3.3.3) — the hardware sorting network the paper
//! evaluated (and rejected for the channel-first cache layout, §3.4.1).
//!
//! The network sorts n = 2^m elements in (log n)(log n + 1)/2 comparison
//! stages; with n/2 parallel comparators each stage is one "cycle", so
//! the parallel depth is O((log n)²) — Fig 12's 8-element example runs in
//! 6 comparator cycles.

use crate::fp16::F16;

/// Cost/trace report of one sort.
#[derive(Clone, Copy, Debug, Default)]
pub struct SortReport {
    /// Total pairwise comparisons performed.
    pub comparisons: u64,
    /// Parallel stages (= cycles with n/2 comparators).
    pub stages: u32,
}

/// In-place bitonic sort, ascending. `xs.len()` must be a power of two
/// (§3.3.3: "the total number of elements must be an integer power of 2").
/// Returns the cost report.
pub fn bitonic_sort(xs: &mut [F16]) -> SortReport {
    let n = xs.len();
    assert!(n.is_power_of_two(), "bitonic sort needs 2^m elements, got {n}");
    let mut rep = SortReport::default();
    let mut k = 2;
    while k <= n {
        let mut j = k / 2;
        while j > 0 {
            rep.stages += 1;
            for i in 0..n {
                let l = i ^ j;
                if l > i {
                    rep.comparisons += 1;
                    let ascending = (i & k) == 0;
                    let a = xs[i].total_cmp_key();
                    let b = xs[l].total_cmp_key();
                    if (ascending && a > b) || (!ascending && a < b) {
                        xs.swap(i, l);
                    }
                }
            }
            j /= 2;
        }
        k *= 2;
    }
    rep
}

/// Max-of-n via the sorting network (what a bitonic max-pooling unit
/// would do) — returns (max, report).
pub fn bitonic_max(values: &[F16]) -> (F16, SortReport) {
    let n = values.len().next_power_of_two();
    let mut padded = vec![F16::NEG_INFINITY; n];
    padded[..values.len()].copy_from_slice(values);
    let rep = bitonic_sort(&mut padded);
    (padded[n - 1], rep)
}

/// Sequential compare chain (what the shipped RTL does, Fig 26): n−1
/// comparisons, n−1 "cycles" at II=1 per comparator... but at II=2 for
/// the accumulating comparator. Returns (max, comparisons).
pub fn sequential_max(values: &[F16]) -> (F16, u64) {
    let mut best = F16::NEG_INFINITY;
    let mut cmps = 0;
    for &v in values {
        cmps += 1;
        if v.gt(best) {
            best = v;
        }
    }
    (best, cmps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{forall, Rng};

    #[test]
    fn sorts_known_sequence() {
        let mut xs: Vec<F16> =
            [3.0f32, -1.0, 7.5, 0.0, -2.25, 8.0, 1.0, 1.0].iter().map(|&v| F16::from_f32(v)).collect();
        let rep = bitonic_sort(&mut xs);
        let vals: Vec<f32> = xs.iter().map(|v| v.to_f32()).collect();
        assert_eq!(vals, vec![-2.25, -1.0, 0.0, 1.0, 1.0, 3.0, 7.5, 8.0]);
        // Fig 12: 8 elements → 6 stages.
        assert_eq!(rep.stages, 6);
        // n/2 · stages comparisons total.
        assert_eq!(rep.comparisons, 4 * 6);
    }

    #[test]
    fn stage_count_is_quadratic_in_log_n() {
        for m in 1..=7u32 {
            let n = 1usize << m;
            let mut xs: Vec<F16> = (0..n).map(|i| F16::from_u32((n - i) as u32)).collect();
            let rep = bitonic_sort(&mut xs);
            assert_eq!(rep.stages, m * (m + 1) / 2, "n={n}");
        }
    }

    #[test]
    fn sort_property_random() {
        forall(
            0xB170,
            300,
            |r: &mut Rng| {
                let m = r.below(6) + 1;
                (0..(1usize << m)).map(|_| F16::from_f32(r.normal(10.0))).collect::<Vec<_>>()
            },
            |xs| {
                let mut sorted = xs.clone();
                bitonic_sort(&mut sorted);
                // Must be a permutation, and non-decreasing.
                let mut a: Vec<u16> = xs.iter().map(|v| v.to_bits()).collect();
                let mut b: Vec<u16> = sorted.iter().map(|v| v.to_bits()).collect();
                a.sort_unstable_by_key(|&v| F16::from_bits(v).total_cmp_key());
                b.sort_unstable_by_key(|&v| F16::from_bits(v).total_cmp_key());
                if a != b {
                    return Err("not a permutation".into());
                }
                for w in sorted.windows(2) {
                    if w[0].total_cmp_key() > w[1].total_cmp_key() {
                        return Err("not sorted".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn bitonic_and_sequential_max_agree() {
        forall(
            0x3A30,
            200,
            |r: &mut Rng| (0..(r.below(60) + 1)).map(|_| F16::from_f32(r.normal(5.0))).collect::<Vec<_>>(),
            |xs| {
                let (a, _) = bitonic_max(xs);
                let (b, _) = sequential_max(xs);
                if a.to_bits() == b.to_bits() {
                    Ok(())
                } else {
                    Err(format!("{a:?} vs {b:?}"))
                }
            },
        );
    }
}
