//! SqueezeNet v1.1 — the paper's verification workload (§4.1, Tables 1–2).
//!
//! Built exactly per Table 2: conv1 3×3/s2, three max-pools, eight fire
//! modules (squeeze1x1 → expand1x1 ‖ expand3x3 → concat), conv10 1×1 to
//! 1000 classes, global 14×14 average pool, softmax.

use super::graph::Network;
use super::layer::LayerSpec;

/// Channel plan of one fire module.
struct Fire {
    name: &'static str,
    squeeze: u32,
    expand: u32,
}

const FIRES: [Fire; 8] = [
    Fire { name: "fire2", squeeze: 16, expand: 64 },
    Fire { name: "fire3", squeeze: 16, expand: 64 },
    Fire { name: "fire4", squeeze: 32, expand: 128 },
    Fire { name: "fire5", squeeze: 32, expand: 128 },
    Fire { name: "fire6", squeeze: 48, expand: 192 },
    Fire { name: "fire7", squeeze: 48, expand: 192 },
    Fire { name: "fire8", squeeze: 64, expand: 256 },
    Fire { name: "fire9", squeeze: 64, expand: 256 },
];

/// Build SqueezeNet v1.1 for a 227×227×3 input (Table 1 dimensions).
pub fn squeezenet_v11() -> Network {
    let mut n = Network::new("squeezenet_v1.1");
    let inp = n.input(227, 3);

    let conv1 = n.engine(LayerSpec::conv("conv1", 3, 2, 0, 227, 3, 64, 0), inp);
    let mut cur = n.engine(LayerSpec::maxpool("pool1", 3, 2, 113, 64), conv1);
    let mut side = 56u32;
    let mut ch = 64u32;

    for (i, fire) in FIRES.iter().enumerate() {
        let squeeze = n.engine(
            LayerSpec::conv(&format!("{}/squeeze1x1", fire.name), 1, 1, 0, side, ch, fire.squeeze, 0),
            cur,
        );
        let e1 = n.engine(
            LayerSpec::conv(&format!("{}/expand1x1", fire.name), 1, 1, 0, side, fire.squeeze, fire.expand, 1),
            squeeze,
        );
        let e3 = n.engine(
            LayerSpec::conv(&format!("{}/expand3x3", fire.name), 3, 1, 1, side, fire.squeeze, fire.expand, 5),
            squeeze,
        );
        cur = n.concat(&format!("{}/concat", fire.name), vec![e1, e3]);
        ch = 2 * fire.expand;
        // pool3 after fire3, pool5 after fire5 (Table 1).
        if i == 1 {
            cur = n.engine(LayerSpec::maxpool("pool3", 3, 2, side, ch), cur);
            side = 28;
        } else if i == 3 {
            cur = n.engine(LayerSpec::maxpool("pool5", 3, 2, side, ch), cur);
            side = 14;
        }
    }

    // drop9 is identity at inference and is skipped (§4.1).
    let conv10 = n.engine(LayerSpec::conv("conv10", 1, 1, 0, 14, 512, 1000, 0), cur);
    let pool10 = n.engine(LayerSpec::avgpool("pool10", 14, 1, 14, 1000), conv10);
    n.softmax("prob", pool10);
    n
}

/// A fire-module micro network for a 32×32×3 input — structurally a
/// miniature SqueezeNet (conv → pool → squeeze → expand pair → concat →
/// conv10 → gap → softmax), small enough that serving sweeps finish in
/// seconds. Shared by `examples/serve.rs` and the serving benches so
/// the two always measure the same workload.
pub fn micro_squeezenet() -> Network {
    let mut n = Network::new("micro_squeezenet");
    let inp = n.input(32, 3);
    let c1 = n.engine(LayerSpec::conv("conv1", 3, 2, 0, 32, 3, 16, 0), inp); // 15
    let p1 = n.engine(LayerSpec::maxpool("pool1", 3, 2, 15, 16), c1); // 7
    let sq = n.engine(LayerSpec::conv("f/squeeze", 1, 1, 0, 7, 16, 8, 0), p1);
    let e1 = n.engine(LayerSpec::conv("f/expand1x1", 1, 1, 0, 7, 8, 16, 1), sq);
    let e3 = n.engine(LayerSpec::conv("f/expand3x3", 3, 1, 1, 7, 8, 16, 5), sq);
    let cat = n.concat("f/concat", vec![e1, e3]);
    let c10 = n.engine(LayerSpec::conv("conv10", 1, 1, 0, 7, 32, 10, 0), cat);
    let gap = n.engine(LayerSpec::avgpool("pool10", 7, 1, 7, 10), c10);
    n.softmax("prob", gap);
    n
}

/// The 26 engine-op rows of Table 2 in order, as (name, command hex) —
/// golden data for the T2 experiment.
pub const TABLE2_COMMANDS: [(&str, &str); 26] = [
    ("conv1", "71E3_0321 0040_0003 0006_0900"),
    ("pool1", "3871_0322 0040_0040 0006_0900"),
    ("fire2/squeeze1x1", "3838_0111 0010_0040 0001_0100"),
    ("fire2/expand1x1", "3838_0111 0040_0010 0001_0110"),
    ("fire2/expand3x3", "3838_0311 0040_0010 0003_0951"),
    ("fire3/squeeze1x1", "3838_0111 0010_0080 0001_0100"),
    ("fire3/expand1x1", "3838_0111 0040_0010 0001_0110"),
    ("fire3/expand3x3", "3838_0311 0040_0010 0003_0951"),
    ("pool3", "1C38_0322 0080_0080 0006_0900"),
    ("fire4/squeeze1x1", "1C1C_0111 0020_0080 0001_0100"),
    ("fire4/expand1x1", "1C1C_0111 0080_0020 0001_0110"),
    ("fire4/expand3x3", "1C1C_0311 0080_0020 0003_0951"),
    ("fire5/squeeze1x1", "1C1C_0111 0020_0100 0001_0100"),
    ("fire5/expand1x1", "1C1C_0111 0080_0020 0001_0110"),
    ("fire5/expand3x3", "1C1C_0311 0080_0020 0003_0951"),
    ("pool5", "0E1C_0322 0100_0100 0006_0900"),
    ("fire6/squeeze1x1", "0E0E_0111 0030_0100 0001_0100"),
    ("fire6/expand1x1", "0E0E_0111 00C0_0030 0001_0110"),
    ("fire6/expand3x3", "0E0E_0311 00C0_0030 0003_0951"),
    ("fire7/squeeze1x1", "0E0E_0111 0030_0180 0001_0100"),
    ("fire7/expand1x1", "0E0E_0111 00C0_0030 0001_0110"),
    ("fire7/expand3x3", "0E0E_0311 00C0_0030 0003_0951"),
    ("fire8/squeeze1x1", "0E0E_0111 0040_0180 0001_0100"),
    ("fire8/expand1x1", "0E0E_0111 0100_0040 0001_0110"),
    ("fire8/expand3x3", "0E0E_0311 0100_0040 0003_0951"),
    ("fire9/squeeze1x1", "0E0E_0111 0040_0200 0001_0100"),
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::graph::Node;

    #[test]
    fn structure_matches_table1() {
        let n = squeezenet_v11();
        n.check().unwrap();
        // Table 1 output dimensions (side, channels) per named node.
        let expect = [
            ("conv1", (113, 64)),
            ("pool1", (56, 64)),
            ("fire2/concat", (56, 128)),
            ("fire3/concat", (56, 128)),
            ("pool3", (28, 128)),
            ("fire4/concat", (28, 256)),
            ("fire5/concat", (28, 256)),
            ("pool5", (14, 256)),
            ("fire6/concat", (14, 384)),
            ("fire7/concat", (14, 384)),
            ("fire8/concat", (14, 512)),
            ("fire9/concat", (14, 512)),
            ("conv10", (14, 1000)),
            ("pool10", (1, 1000)),
        ];
        for (name, shape) in expect {
            let i = n.find(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(n.out_shape(i), shape, "{name}");
        }
    }

    #[test]
    fn engine_op_count_matches_table2() {
        let n = squeezenet_v11();
        // 26 conv/pool ops: conv1 + 3 pools + 8 fires × 3 convs + conv10
        // + pool10 = 1+3+24+2 = 30? Table 2 lists conv ops: conv1(1),
        // pool1, 8 fires × 3, pool3, pool5, conv10, pool10 = 30.
        assert_eq!(n.engine_layers().len(), 30);
    }

    #[test]
    fn commands_match_table2_golden() {
        let n = squeezenet_v11();
        for (name, hex) in TABLE2_COMMANDS {
            let i = n.find(name).unwrap_or_else(|| panic!("missing {name}"));
            if let Node::Engine { spec, .. } = &n.nodes[i] {
                assert_eq!(spec.command_hex(), hex, "{name}");
            } else {
                panic!("{name} is not an engine node");
            }
        }
    }

    #[test]
    fn micro_squeezenet_is_consistent() {
        let n = micro_squeezenet();
        n.check().unwrap();
        assert_eq!(n.engine_layers().len(), 7);
        let gap = n.find("pool10").unwrap();
        assert_eq!(n.out_shape(gap), (1, 10));
    }

    #[test]
    fn total_weights_about_1_24m() {
        // SqueezeNet v1.1 has ~1.235M parameters; with channel padding on
        // conv1 (3→8) plus biases the device-transferred total is slightly
        // higher. Sanity band.
        let n = squeezenet_v11();
        let total = n.total_weights();
        assert!(total > 1_200_000 && total < 1_300_000, "{total}");
    }

    #[test]
    fn total_macs_order_of_magnitude() {
        // ~390M MACs for SqueezeNet v1.1 at 227×227 (with conv1 unpadded
        // channel count 3 this lands near 360M; padded-lane count is
        // higher). Assert the right ballpark.
        let n = squeezenet_v11();
        let macs = n.total_macs();
        assert!(macs > 250_000_000 && macs < 500_000_000, "{macs}");
    }
}
