//! GoogLeNet (LRN-free) — the second network the paper names as
//! LRN-free-capable (§3.2: "there are AlexNet and GoogLeNet without LRN
//! proposed"). Exercises everything SqueezeNet doesn't:
//!
//! * 4-way inception concats (vs SqueezeNet's 2-way fire modules),
//!   including the max-pool projection branch — which needs *padded*
//!   "same" pooling (3×3/s1/p1), driving the `maxpool_padded` /
//!   `pool_pad` machinery through the whole device stack;
//! * 7×7/s2 stem convolution (pixel-granularity GEMM slicing);
//! * a 7×7 global average pool.
//!
//! Geometry follows Szegedy et al. 2015 at 227×227 input (stem conv
//! pad 3 → 114 … global pool 7×7); LRN layers are dropped per §3.2.

use super::graph::Network;
use super::layer::LayerSpec;

/// One inception module's channel plan.
#[allow(clippy::too_many_arguments)]
fn inception(
    n: &mut Network,
    name: &str,
    input: usize,
    side: u32,
    in_ch: u32,
    c1: u32,
    c3r: u32,
    c3: u32,
    c5r: u32,
    c5: u32,
    pp: u32,
) -> (usize, u32) {
    let b1 = n.engine(LayerSpec::conv(&format!("{name}/1x1"), 1, 1, 0, side, in_ch, c1, 0), input);
    let r3 = n.engine(
        LayerSpec::conv(&format!("{name}/3x3_reduce"), 1, 1, 0, side, in_ch, c3r, 0),
        input,
    );
    let b3 = n.engine(LayerSpec::conv(&format!("{name}/3x3"), 3, 1, 1, side, c3r, c3, 0), r3);
    let r5 = n.engine(
        LayerSpec::conv(&format!("{name}/5x5_reduce"), 1, 1, 0, side, in_ch, c5r, 0),
        input,
    );
    let b5 = n.engine(LayerSpec::conv(&format!("{name}/5x5"), 5, 1, 2, side, c5r, c5, 0), r5);
    // The pool-projection branch: "same" max pooling then 1×1 conv.
    let mp = n.engine(
        LayerSpec::maxpool_padded(&format!("{name}/pool"), 3, 1, 1, side, in_ch),
        input,
    );
    let bp = n.engine(LayerSpec::conv(&format!("{name}/pool_proj"), 1, 1, 0, side, in_ch, pp, 0), mp);
    let cat = n.concat(&format!("{name}/output"), vec![b1, b3, b5, bp]);
    (cat, c1 + c3 + c5 + pp)
}

/// Build GoogLeNet (inception v1, LRN-free) for a 227×227×3 input.
pub fn googlenet() -> Network {
    let mut n = Network::new("googlenet");
    let inp = n.input(227, 3);
    // Stem: 7×7/2 pad 3 → 114; pool/2 → 57; 1×1; 3×3 pad 1; pool/2 → 28.
    let c1 = n.engine(LayerSpec::conv("conv1/7x7_s2", 7, 2, 3, 227, 3, 64, 0), inp);
    let p1 = n.engine(LayerSpec::maxpool("pool1/3x3_s2", 3, 2, 114, 64), c1); // 57
    let c2r = n.engine(LayerSpec::conv("conv2/3x3_reduce", 1, 1, 0, 57, 64, 64, 0), p1);
    let c2 = n.engine(LayerSpec::conv("conv2/3x3", 3, 1, 1, 57, 64, 192, 0), c2r);
    let p2 = n.engine(LayerSpec::maxpool("pool2/3x3_s2", 3, 2, 57, 192), c2); // 29

    let side = n.out_shape(p2).0;
    let (i3a, ch) = inception(&mut n, "inception_3a", p2, side, 192, 64, 96, 128, 16, 32, 32);
    let (i3b, ch) = inception(&mut n, "inception_3b", i3a, side, ch, 128, 128, 192, 32, 96, 64);
    debug_assert_eq!(ch, 480);
    let p3 = n.engine(LayerSpec::maxpool("pool3/3x3_s2", 3, 2, side, ch), i3b);

    let side = n.out_shape(p3).0;
    let (i4a, ch) = inception(&mut n, "inception_4a", p3, side, 480, 192, 96, 208, 16, 48, 64);
    let (i4b, ch) = inception(&mut n, "inception_4b", i4a, side, ch, 160, 112, 224, 24, 64, 64);
    let (i4c, ch) = inception(&mut n, "inception_4c", i4b, side, ch, 128, 128, 256, 24, 64, 64);
    let (i4d, ch) = inception(&mut n, "inception_4d", i4c, side, ch, 112, 144, 288, 32, 64, 64);
    let (i4e, ch) = inception(&mut n, "inception_4e", i4d, side, ch, 256, 160, 320, 32, 128, 128);
    debug_assert_eq!(ch, 832);
    let p4 = n.engine(LayerSpec::maxpool("pool4/3x3_s2", 3, 2, side, ch), i4e);

    let side = n.out_shape(p4).0;
    let (i5a, ch) = inception(&mut n, "inception_5a", p4, side, 832, 256, 160, 320, 32, 128, 128);
    let (i5b, ch) = inception(&mut n, "inception_5b", i5a, side, ch, 384, 192, 384, 48, 128, 128);
    debug_assert_eq!(ch, 1024);

    let gap = n.engine(LayerSpec::avgpool("pool5/avg", side, 1, side, ch), i5b);
    // loss3/classifier is a FC = 1×1 conv to 1000 classes, no ReLU.
    let mut fc = LayerSpec::conv("loss3/classifier", 1, 1, 0, 1, 1024, 1000, 0);
    fc.skip_relu = true;
    let fc = n.engine(fc, gap);
    n.softmax("prob", fc);
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::stream::StreamAccelerator;
    use crate::host::driver::{forward_functional, HostDriver};
    use crate::hw::usb::UsbLink;
    use crate::net::tensor::Tensor;
    use crate::net::weights::synthesize_weights;
    use crate::prop::Rng;

    #[test]
    fn structure_checks_out() {
        let net = googlenet();
        net.check().unwrap();
        assert_eq!(net.out_shape(net.find("conv1/7x7_s2").unwrap()), (114, 64));
        assert_eq!(net.out_shape(net.find("inception_3a/output").unwrap()).1, 256);
        assert_eq!(net.out_shape(net.find("inception_5b/output").unwrap()).1, 1024);
        assert_eq!(net.out_shape(net.find("loss3/classifier").unwrap()), (1, 1000));
        // 2 convs per reduce-branch etc: 6 convs + 1 pool per inception ×9
        // + stem/classifier: substantial layer count.
        assert!(net.engine_layers().len() > 60, "{}", net.engine_layers().len());
    }

    #[test]
    fn same_pooling_keeps_surface() {
        let spec = LayerSpec::maxpool_padded("p", 3, 1, 1, 28, 16);
        assert_eq!(spec.o_side, 28);
        // command round-trips with padding in the low nibble.
        let d = spec.encode();
        let back = LayerSpec::decode("p", d).unwrap();
        assert_eq!(back.padding, 1);
        assert_eq!(back.o_side, 28);
    }

    #[test]
    fn padded_maxpool_matches_reference_semantics() {
        // "same" pooling: each output = max of the 3×3 neighborhood with
        // borders clipped; compare against a direct computation.
        let mut rng = Rng::new(0x611);
        let side = 6;
        let vals: Vec<f32> = (0..side * side * 8).map(|_| rng.normal(1.0).abs()).collect();
        let inp = Tensor::from_vec(side, side, 8, vals.clone()).to_f16();
        let spec = LayerSpec::maxpool_padded("p", 3, 1, 1, side as u32, 8);
        let out = crate::engine::functional::maxpool(&spec, &inp);
        assert_eq!(out.h, side);
        let f32in = Tensor::from_vec(side, side, 8, vals);
        for y in 0..side {
            for x in 0..side {
                for c in 0..8 {
                    let mut best = 0f32; // RTL 0-init
                    for ky in 0..3usize {
                        for kx in 0..3usize {
                            let (iy, ix) = (y + ky, x + kx);
                            if iy < 1 || ix < 1 || iy > side || ix > side {
                                continue;
                            }
                            let v = crate::fp16::F16::from_f32(f32in.get(iy - 1, ix - 1, c)).to_f32();
                            best = best.max(v);
                        }
                    }
                    assert_eq!(out.get(y, x, c).to_f32(), best, "({y},{x},{c})");
                }
            }
        }
    }

    #[test]
    fn inception_module_runs_on_device_bit_exact() {
        // One inception module end-to-end through the sliced device flow
        // vs the functional engine — covers the padded-pool slicing path.
        let mut n = Network::new("inception_mini");
        let inp = n.input(10, 16);
        let (_, ch) = inception(&mut n, "inc", inp, 10, 16, 8, 4, 8, 4, 8, 8);
        assert_eq!(ch, 32);
        n.check().unwrap();
        let blobs = synthesize_weights(&n, 21);
        let mut rng = Rng::new(3);
        let img = Tensor::from_vec(10, 10, 16, (0..10 * 10 * 16).map(|_| rng.normal(1.0)).collect());
        let reference = forward_functional(&n, &blobs, &img).unwrap();
        let mut dev = StreamAccelerator::new(UsbLink::usb3_frontpanel());
        let res = HostDriver::new(&mut dev).forward(&n, &blobs, &img).unwrap();
        for (i, (a, b)) in res.outputs.iter().zip(&reference).enumerate() {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "node {} ({})", i, n.node_name(i));
            }
        }
    }

    #[test]
    fn googlenet_macs_about_1_5g() {
        let net = googlenet();
        let macs = net.total_macs();
        // GoogLeNet ≈ 1.5 G MACs at 224/227 input.
        assert!(macs > 1_000_000_000 && macs < 2_500_000_000, "{macs}");
    }
}
