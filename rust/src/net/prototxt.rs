//! Caffe `.prototxt` parser → [`Network`].
//!
//! The paper lists this as future work (§6.2: "After the architecture is
//! fixed, the commands can be extracted from prototxt by python script" —
//! the author extracted Table 2 by hand). We implement it as a first-class
//! feature, in Rust, so a user can point the CLI at any
//! Convolution/ReLU/Pooling/Concat/Dropout/Softmax prototxt and get the
//! command stream directly.
//!
//! Grammar subset: `key: value` scalars (numbers, quoted strings,
//! identifiers) and `key { ... }` nested messages, with repeated keys.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;

use super::graph::Network;
use super::layer::LayerSpec;

/// A parsed prototxt value.
#[derive(Clone, Debug, PartialEq)]
pub enum PVal {
    Str(String),
    Num(f64),
    Ident(String),
    Block(PBlock),
}

/// A message: ordered multimap of field → value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PBlock {
    pub entries: Vec<(String, PVal)>,
}

impl PBlock {
    /// All values for a repeated field.
    pub fn all(&self, key: &str) -> Vec<&PVal> {
        self.entries.iter().filter(|(k, _)| k == key).map(|(_, v)| v).collect()
    }

    /// First value for a field.
    pub fn first(&self, key: &str) -> Option<&PVal> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        match self.first(key)? {
            PVal::Str(s) | PVal::Ident(s) => Some(s),
            _ => None,
        }
    }

    pub fn num(&self, key: &str) -> Option<f64> {
        match self.first(key)? {
            PVal::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn block(&self, key: &str) -> Option<&PBlock> {
        match self.first(key)? {
            PVal::Block(b) => Some(b),
            _ => None,
        }
    }
}

struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
}

#[derive(Debug, PartialEq, Clone)]
enum Tok {
    Ident(String),
    Str(String),
    Num(f64),
    Colon,
    LBrace,
    RBrace,
    Eof,
}

impl<'a> Lexer<'a> {
    fn new(src: &'a str) -> Lexer<'a> {
        Lexer { src: src.as_bytes(), pos: 0 }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.src.len() {
            let c = self.src[self.pos];
            if c == b'#' {
                while self.pos < self.src.len() && self.src[self.pos] != b'\n' {
                    self.pos += 1;
                }
            } else if c.is_ascii_whitespace() || c == b',' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn next(&mut self) -> Result<Tok> {
        self.skip_ws();
        if self.pos >= self.src.len() {
            return Ok(Tok::Eof);
        }
        let c = self.src[self.pos];
        match c {
            b':' => {
                self.pos += 1;
                Ok(Tok::Colon)
            }
            b'{' => {
                self.pos += 1;
                Ok(Tok::LBrace)
            }
            b'}' => {
                self.pos += 1;
                Ok(Tok::RBrace)
            }
            b'"' | b'\'' => {
                let quote = c;
                self.pos += 1;
                let start = self.pos;
                while self.pos < self.src.len() && self.src[self.pos] != quote {
                    self.pos += 1;
                }
                if self.pos >= self.src.len() {
                    bail!("unterminated string");
                }
                let s = std::str::from_utf8(&self.src[start..self.pos])?.to_string();
                self.pos += 1;
                Ok(Tok::Str(s))
            }
            _ if c == b'-' || c == b'+' || c.is_ascii_digit() => {
                let start = self.pos;
                self.pos += 1;
                while self.pos < self.src.len()
                    && (self.src[self.pos].is_ascii_alphanumeric()
                        || self.src[self.pos] == b'.'
                        || self.src[self.pos] == b'-'
                        || self.src[self.pos] == b'+')
                {
                    self.pos += 1;
                }
                let s = std::str::from_utf8(&self.src[start..self.pos])?;
                let n: f64 = s.parse().with_context(|| format!("bad number {s:?}"))?;
                Ok(Tok::Num(n))
            }
            _ if c.is_ascii_alphabetic() || c == b'_' => {
                let start = self.pos;
                while self.pos < self.src.len()
                    && (self.src[self.pos].is_ascii_alphanumeric() || self.src[self.pos] == b'_')
                {
                    self.pos += 1;
                }
                Ok(Tok::Ident(std::str::from_utf8(&self.src[start..self.pos])?.to_string()))
            }
            _ => bail!("unexpected character {:?} at byte {}", c as char, self.pos),
        }
    }
}

/// Parse prototxt text into its root message.
pub fn parse(src: &str) -> Result<PBlock> {
    let mut lex = Lexer::new(src);
    parse_block(&mut lex, true)
}

fn parse_block(lex: &mut Lexer, top: bool) -> Result<PBlock> {
    let mut block = PBlock::default();
    loop {
        let tok = lex.next()?;
        match tok {
            Tok::Eof => {
                if top {
                    return Ok(block);
                }
                bail!("unexpected EOF inside block");
            }
            Tok::RBrace => {
                if top {
                    bail!("unmatched '}}'");
                }
                return Ok(block);
            }
            Tok::Ident(key) => {
                let tok2 = lex.next()?;
                match tok2 {
                    Tok::Colon => {
                        let v = match lex.next()? {
                            Tok::Str(s) => PVal::Str(s),
                            Tok::Num(n) => PVal::Num(n),
                            Tok::Ident(id) => PVal::Ident(id),
                            Tok::LBrace => PVal::Block(parse_block(lex, false)?),
                            t => bail!("bad value after '{key}:': {t:?}"),
                        };
                        block.entries.push((key, v));
                    }
                    Tok::LBrace => {
                        block.entries.push((key, PVal::Block(parse_block(lex, false)?)));
                    }
                    t => bail!("expected ':' or '{{' after {key:?}, got {t:?}"),
                }
            }
            t => bail!("expected field name, got {t:?}"),
        }
    }
}

/// Build a [`Network`] from a parsed prototxt. Supports the layer types
/// the accelerator handles: Input, Convolution (+fused ReLU), Pooling
/// (MAX/AVE), Concat, Dropout (identity), Softmax. Flatten is absorbed.
pub fn build_network(root: &PBlock) -> Result<Network> {
    let name = root.str("name").unwrap_or("prototxt_net").to_string();
    let mut net = Network::new(&name);

    // blob name -> (node index, side, channels)
    let mut blobs: HashMap<String, (usize, u32, u32)> = HashMap::new();
    // conv layers awaiting a ReLU: node index by top blob.
    let mut conv_nodes: HashMap<String, usize> = HashMap::new();

    let layers: Vec<&PBlock> = root
        .all("layer")
        .into_iter()
        .filter_map(|v| match v {
            PVal::Block(b) => Some(b),
            _ => None,
        })
        .collect();
    if layers.is_empty() {
        bail!("no 'layer' blocks found");
    }

    for layer in &layers {
        let lname = layer.str("name").context("layer missing name")?.to_string();
        let ltype = layer.str("type").context("layer missing type")?.to_string();
        let bottoms: Vec<String> = layer
            .all("bottom")
            .iter()
            .filter_map(|v| match v {
                PVal::Str(s) | PVal::Ident(s) => Some(s.clone()),
                _ => None,
            })
            .collect();
        let top = layer.str("top").unwrap_or(&lname).to_string();

        let lookup = |blobs: &HashMap<String, (usize, u32, u32)>, b: &str| -> Result<(usize, u32, u32)> {
            blobs.get(b).copied().with_context(|| format!("{lname}: unknown bottom {b:?}"))
        };

        match ltype.as_str() {
            "Input" => {
                let shape = layer
                    .block("input_param")
                    .and_then(|p| p.block("shape"))
                    .context("Input layer needs input_param { shape { dim... } }")?;
                let dims: Vec<u32> = shape
                    .all("dim")
                    .iter()
                    .filter_map(|v| match v {
                        PVal::Num(n) => Some(*n as u32),
                        _ => None,
                    })
                    .collect();
                // Caffe dims are NCHW.
                if dims.len() != 4 || dims[2] != dims[3] {
                    bail!("Input must be NCHW square, got {dims:?}");
                }
                let idx = net.input(dims[2], dims[1]);
                blobs.insert(top, (idx, dims[2], dims[1]));
            }
            "Convolution" => {
                let p = layer.block("convolution_param").context("missing convolution_param")?;
                let o_ch = p.num("num_output").context("num_output")? as u32;
                let k = p.num("kernel_size").unwrap_or(1.0) as u32;
                let stride = p.num("stride").unwrap_or(1.0) as u32;
                let pad = p.num("pad").unwrap_or(0.0) as u32;
                let (inode, side, ch) = lookup(&blobs, &bottoms[0])?;
                let mut spec = LayerSpec::conv(&lname, k, stride, pad, side, ch, o_ch, 0);
                spec.skip_relu = true; // cleared if a ReLU follows
                let idx = net.engine(spec, inode);
                conv_nodes.insert(top.clone(), idx);
                let o_side = (side + 2 * pad - k) / stride + 1;
                blobs.insert(top, (idx, o_side, o_ch));
            }
            "ReLU" => {
                // In-place in Caffe (bottom == top): fuse into the conv.
                let b = &bottoms[0];
                if let Some(&idx) = conv_nodes.get(b) {
                    if let super::graph::Node::Engine { spec, .. } = &mut net.nodes[idx] {
                        spec.skip_relu = false;
                    }
                    if top != *b {
                        let e = blobs[b];
                        blobs.insert(top, e);
                    }
                } else {
                    // ReLU over a pool/concat output: emit a host-side
                    // Relu node; the command-stream compiler folds it
                    // into max-pooling where the datapath absorbs it.
                    let (inode, side, ch) = lookup(&blobs, b)?;
                    let idx = net.relu(&lname, inode);
                    if top == *b {
                        // In-place: downstream readers of the blob see
                        // the activation. A non-in-place ReLU leaves the
                        // bottom blob raw (Caffe semantics) — other
                        // consumers keep the pre-activation values.
                        blobs.insert(b.clone(), (idx, side, ch));
                    }
                    blobs.insert(top, (idx, side, ch));
                }
            }
            "Pooling" => {
                let p = layer.block("pooling_param").context("missing pooling_param")?;
                let pool = p.str("pool").unwrap_or("MAX").to_string();
                let (inode, side, ch) = lookup(&blobs, &bottoms[0])?;
                let global = matches!(p.str("global_pooling"), Some("true"))
                    || p.num("global_pooling").is_some();
                let k = if global { side } else { p.num("kernel_size").context("kernel_size")? as u32 };
                let stride = p.num("stride").unwrap_or(1.0) as u32;
                let spec = match pool.as_str() {
                    "MAX" => LayerSpec::maxpool(&lname, k, stride, side, ch),
                    "AVE" => LayerSpec::avgpool(&lname, k, stride, side, ch),
                    other => bail!("{lname}: unsupported pool {other:?}"),
                };
                let o_side = spec.o_side;
                let idx = net.engine(spec, inode);
                blobs.insert(top, (idx, o_side, ch));
            }
            "Concat" => {
                let mut inputs = Vec::new();
                let mut side = 0;
                let mut ch = 0;
                for b in &bottoms {
                    let (idx, s, c) = lookup(&blobs, b)?;
                    inputs.push(idx);
                    side = s;
                    ch += c;
                }
                // Tag parallel conv branches with the paper's slot values:
                // Table 2 uses 1 for expand1x1 and 5 for expand3x3 (the
                // draft encoding of §4.4 is inconsistent with the shipped
                // table; we follow the table for 2-way concats and the
                // §4.4 formula — count in bits [3:2], position in [1:0] —
                // beyond that).
                let count = inputs.len() as u32 - 1;
                for (pos, &idx) in inputs.iter().enumerate() {
                    if let super::graph::Node::Engine { spec, .. } = &mut net.nodes[idx] {
                        spec.slot = if inputs.len() == 2 {
                            if pos == 0 { 1 } else { 5 }
                        } else {
                            (count << 2) | pos as u32
                        };
                    }
                }
                let idx = net.concat(&lname, inputs);
                blobs.insert(top, (idx, side, ch));
            }
            "Dropout" | "Flatten" | "Reshape" => {
                // Identity at inference: alias the blob.
                let e = lookup(&blobs, &bottoms[0])?;
                blobs.insert(top, e);
            }
            "Softmax" => {
                let (inode, side, ch) = lookup(&blobs, &bottoms[0])?;
                let idx = net.softmax(&lname, inode);
                blobs.insert(top, (idx, side, ch));
            }
            "LRN" => bail!("{lname}: LRN is not implemented by the accelerator (§3.2)"),
            other => bail!("{lname}: unsupported layer type {other:?}"),
        }
    }
    net.check().map_err(|e| anyhow::anyhow!(e))?;
    Ok(net)
}

/// Convenience: parse + build from a file.
pub fn load(path: &std::path::Path) -> Result<Network> {
    let src = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    build_network(&parse(&src)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = r#"
name: "tiny"
# a comment
layer { name: "data" type: "Input" top: "data"
  input_param { shape { dim: 1 dim: 3 dim: 8 dim: 8 } } }
layer { name: "conv1" type: "Convolution" bottom: "data" top: "conv1"
  convolution_param { num_output: 4 kernel_size: 3 stride: 1 pad: 1 } }
layer { name: "relu1" type: "ReLU" bottom: "conv1" top: "conv1" }
layer { name: "e1" type: "Convolution" bottom: "conv1" top: "e1"
  convolution_param { num_output: 4 kernel_size: 1 } }
layer { name: "relu_e1" type: "ReLU" bottom: "e1" top: "e1" }
layer { name: "e3" type: "Convolution" bottom: "conv1" top: "e3"
  convolution_param { num_output: 4 kernel_size: 3 pad: 1 } }
layer { name: "relu_e3" type: "ReLU" bottom: "e3" top: "e3" }
layer { name: "cat" type: "Concat" bottom: "e1" bottom: "e3" top: "cat" }
layer { name: "pool" type: "Pooling" bottom: "cat" top: "pool"
  pooling_param { pool: AVE kernel_size: 8 stride: 1 } }
layer { name: "prob" type: "Softmax" bottom: "pool" top: "prob" }
"#;

    #[test]
    fn parses_tokens_and_structure() {
        let root = parse(TINY).unwrap();
        assert_eq!(root.str("name"), Some("tiny"));
        assert_eq!(root.all("layer").len(), 10);
    }

    #[test]
    fn builds_network_with_fused_relu_and_slots() {
        let net = build_network(&parse(TINY).unwrap()).unwrap();
        net.check().unwrap();
        let layers = net.engine_layers();
        let conv1 = layers.iter().find(|s| s.name == "conv1").unwrap();
        assert!(!conv1.skip_relu); // ReLU fused
        let e1 = layers.iter().find(|s| s.name == "e1").unwrap();
        let e3 = layers.iter().find(|s| s.name == "e3").unwrap();
        assert_eq!(e1.slot, 1); // Table 2 convention for expand1x1
        assert_eq!(e3.slot, 5); // expand3x3
        assert_eq!(net.out_shape(net.find("pool").unwrap()), (1, 8));
    }

    #[test]
    fn relu_on_pool_output_becomes_host_node() {
        let src = r#"
layer { name: "data" type: "Input" top: "data"
  input_param { shape { dim: 1 dim: 8 dim: 8 dim: 8 } } }
layer { name: "pool" type: "Pooling" bottom: "data" top: "pool"
  pooling_param { pool: MAX kernel_size: 2 stride: 2 } }
layer { name: "relu_p" type: "ReLU" bottom: "pool" top: "pool" }
layer { name: "prob" type: "Softmax" bottom: "pool" top: "prob" }
"#;
        let net = build_network(&parse(src).unwrap()).unwrap();
        net.check().unwrap();
        let r = net.find("relu_p").expect("host relu node emitted");
        assert_eq!(net.out_shape(r), (4, 8));
        // Downstream consumers read the relu'd blob.
        match &net.nodes[net.find("prob").unwrap()] {
            super::super::graph::Node::Softmax { input, .. } => assert_eq!(*input, r),
            other => panic!("unexpected node {other:?}"),
        }
    }

    #[test]
    fn non_inplace_relu_keeps_bottom_blob_raw() {
        // `relu_p` writes a NEW top blob; a later consumer of the raw
        // "pool" blob must keep reading pre-activation values.
        let src = r#"
layer { name: "data" type: "Input" top: "data"
  input_param { shape { dim: 1 dim: 8 dim: 8 dim: 8 } } }
layer { name: "pool" type: "Pooling" bottom: "data" top: "pool"
  pooling_param { pool: MAX kernel_size: 2 stride: 2 } }
layer { name: "relu_p" type: "ReLU" bottom: "pool" top: "pool_r" }
layer { name: "c_act" type: "Convolution" bottom: "pool_r" top: "c_act"
  convolution_param { num_output: 4 kernel_size: 1 } }
layer { name: "c_raw" type: "Convolution" bottom: "pool" top: "c_raw"
  convolution_param { num_output: 4 kernel_size: 1 } }
layer { name: "cat" type: "Concat" bottom: "c_act" bottom: "c_raw" top: "cat" }
layer { name: "prob" type: "Softmax" bottom: "cat" top: "prob" }
"#;
        let net = build_network(&parse(src).unwrap()).unwrap();
        net.check().unwrap();
        let pool = net.find("pool").unwrap();
        let relu = net.find("relu_p").unwrap();
        let input_of = |name: &str| match &net.nodes[net.find(name).unwrap()] {
            super::super::graph::Node::Engine { input, .. } => *input,
            other => panic!("unexpected node {other:?}"),
        };
        assert_eq!(input_of("c_act"), relu, "top blob reads the activation");
        assert_eq!(input_of("c_raw"), pool, "bottom blob stays pre-activation");
    }

    #[test]
    fn rejects_lrn() {
        let src = r#"
layer { name: "data" type: "Input" top: "data"
  input_param { shape { dim: 1 dim: 3 dim: 8 dim: 8 } } }
layer { name: "n" type: "LRN" bottom: "data" top: "n" }
"#;
        assert!(build_network(&parse(src).unwrap()).is_err());
    }

    #[test]
    fn error_on_unknown_bottom() {
        let src = r#"
layer { name: "c" type: "Convolution" bottom: "ghost" top: "c"
  convolution_param { num_output: 1 kernel_size: 1 } }
"#;
        assert!(build_network(&parse(src).unwrap()).is_err());
    }

    #[test]
    fn lexer_handles_quotes_comments_negatives() {
        let root = parse("a: -1.5 b: \"x # y\" # trailing\nc { d: 2 }").unwrap();
        assert_eq!(root.num("a"), Some(-1.5));
        assert_eq!(root.str("b"), Some("x # y"));
        assert_eq!(root.block("c").unwrap().num("d"), Some(2.0));
    }
}
