//! AlexNet (LRN-free variant) — the paper's runtime-reconfigurability
//! claim (§6.2): "other networks like AlexNet are also supported" because
//! the engine scale does not depend on network shape. LRN layers are not
//! implemented by the accelerator (§3.2), so this is the LRN-free AlexNet
//! the paper references; fully connected layers are expressed as
//! convolutions (§3.2: "fully connected layers are merged to convolutional
//! layers").

use super::graph::Network;
use super::layer::LayerSpec;

/// Build AlexNet (without LRN) for a 227×227×3 input.
///
/// conv1 11×11/s4 → pool → conv2 5×5 (pad 2) → pool → conv3..5 3×3 →
/// pool → fc6 as 6×6 conv → fc7/fc8 as 1×1 convs → softmax.
/// fc8 has no ReLU — it uses the `skip_relu` command extension.
pub fn alexnet() -> Network {
    alexnet_with_tail("alexnet", 512, 512)
}

/// Classic full-size AlexNet tail: 4096-wide fc6/fc7 and the 1000-class
/// fc8. fc6's 6×6 window over 256 channels is a 1152-word GEMM slice —
/// larger than the whole data cache — so this network requires the
/// [`crate::host::gemm::ConvGranularity::ChannelSplit`] path (the
/// downscaled [`alexnet`] tail has the same slice shape; the full width
/// is purely an output-channel count and the drivers re-slice those in
/// super-blocks either way).
pub fn alexnet_full_tail() -> Network {
    alexnet_with_tail("alexnet_full", 4096, 4096)
}

fn alexnet_with_tail(name: &str, fc6_ch: u32, fc7_ch: u32) -> Network {
    let mut n = Network::new(name);
    let inp = n.input(227, 3);

    let conv1 = n.engine(LayerSpec::conv("conv1", 11, 4, 0, 227, 3, 96, 0), inp); // 55
    let pool1 = n.engine(LayerSpec::maxpool("pool1", 3, 2, 55, 96), conv1); // 27
    let conv2 = n.engine(LayerSpec::conv("conv2", 5, 1, 2, 27, 96, 256, 0), pool1); // 27
    let pool2 = n.engine(LayerSpec::maxpool("pool2", 3, 2, 27, 256), conv2); // 13
    let conv3 = n.engine(LayerSpec::conv("conv3", 3, 1, 1, 13, 256, 384, 0), pool2);
    let conv4 = n.engine(LayerSpec::conv("conv4", 3, 1, 1, 13, 384, 384, 0), conv3);
    let conv5 = n.engine(LayerSpec::conv("conv5", 3, 1, 1, 13, 384, 256, 0), conv4);
    let pool5 = n.engine(LayerSpec::maxpool("pool5", 3, 2, 13, 256), conv5); // 6

    // FC layers as convolutions (§3.2). fc6/fc7 width is a parameter:
    // 4096 for the classic network, 512 for the quicker default — the
    // fc6 *slice* shape (6×6 over 256 ch, channel-split) is identical.
    let fc6 = n.engine(LayerSpec::conv("fc6", 6, 1, 0, 6, 256, fc6_ch, 0), pool5); // 1×1
    let fc7 = n.engine(LayerSpec::conv("fc7", 1, 1, 0, 1, fc6_ch, fc7_ch, 0), fc6);
    let mut fc8_spec = LayerSpec::conv("fc8", 1, 1, 0, 1, fc7_ch, 1000, 0);
    fc8_spec.skip_relu = true;
    let fc8 = n.engine(fc8_spec, fc7);
    n.softmax("prob", fc8);
    n
}

/// Just the AlexNet classifier tail, parameterized: the 6×6×256
/// channel-split fc6 (the exact slice shape that used to fail in both
/// drivers), a 1×1 fc7 and a `skip_relu` 1×1 fc8 — small enough for
/// end-to-end bit-identity tests and the serving bench to run the
/// giant-kernel path without paying for the full feature extractor.
pub fn fc6_tail(fc_ch: u32, classes: u32) -> Network {
    let mut n = Network::new("fc6_tail");
    let inp = n.input(6, 256);
    let fc6 = n.engine(LayerSpec::conv("fc6", 6, 1, 0, 6, 256, fc_ch, 0), inp);
    let fc7 = n.engine(LayerSpec::conv("fc7", 1, 1, 0, 1, fc_ch, fc_ch, 0), fc6);
    let mut fc8_spec = LayerSpec::conv("fc8", 1, 1, 0, 1, fc_ch, classes, 0);
    fc8_spec.skip_relu = true;
    let fc8 = n.engine(fc8_spec, fc7);
    n.softmax("prob", fc8);
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_check_out() {
        let n = alexnet();
        n.check().unwrap();
        assert_eq!(n.out_shape(n.find("conv1").unwrap()), (55, 96));
        assert_eq!(n.out_shape(n.find("pool5").unwrap()), (6, 256));
        assert_eq!(n.out_shape(n.find("fc8").unwrap()), (1, 1000));
    }

    #[test]
    fn fc8_skips_relu_via_extension_bit() {
        let n = alexnet();
        let specs = n.engine_layers();
        let fc8 = specs.iter().find(|s| s.name == "fc8").unwrap();
        assert!(fc8.skip_relu);
        let d = fc8.encode();
        assert_eq!(d[0] & 0xF, 0x9); // conv(1) | skip_relu(8)
        let back = super::super::layer::LayerSpec::decode("fc8", d).unwrap();
        assert!(back.skip_relu);
    }

    #[test]
    fn full_tail_restores_classic_widths() {
        let n = alexnet_full_tail();
        n.check().unwrap();
        assert_eq!(n.out_shape(n.find("fc6").unwrap()), (1, 4096));
        assert_eq!(n.out_shape(n.find("fc7").unwrap()), (1, 4096));
        assert_eq!(n.out_shape(n.find("fc8").unwrap()), (1, 1000));
        // fc6 needs the channel-split path in both variants.
        use crate::host::gemm::{conv_granularity, ConvGranularity};
        assert_eq!(conv_granularity(6, 6, 256), ConvGranularity::ChannelSplit);
    }

    #[test]
    fn fc6_tail_is_the_failing_slice_shape() {
        let n = fc6_tail(16, 10);
        n.check().unwrap();
        let fc6 = n.engine_layers()[0].clone();
        assert_eq!((fc6.kernel, fc6.i_ch), (6, 256));
        assert_eq!(n.out_shape(n.find("fc8").unwrap()), (1, 10));
        // 6·6·256 = 9216 values = 1152 cache words > 1024.
        assert!(6 * 6 * 256 / 8 > crate::accel::stream::DATA_CACHE_WORDS);
    }

    #[test]
    fn alexnet_macs_exceed_squeezenet() {
        // The 11×11 conv1 and 5×5 conv2 dominate; AlexNet has far more
        // MACs than SqueezeNet (the motivation for SqueezeNet, §4.1).
        let a = alexnet().total_macs();
        let s = crate::net::squeezenet::squeezenet_v11().total_macs();
        assert!(a > s, "alexnet {a} vs squeezenet {s}");
    }
}
