//! AlexNet (LRN-free variant) — the paper's runtime-reconfigurability
//! claim (§6.2): "other networks like AlexNet are also supported" because
//! the engine scale does not depend on network shape. LRN layers are not
//! implemented by the accelerator (§3.2), so this is the LRN-free AlexNet
//! the paper references; fully connected layers are expressed as
//! convolutions (§3.2: "fully connected layers are merged to convolutional
//! layers").

use super::graph::Network;
use super::layer::LayerSpec;

/// Build AlexNet (without LRN) for a 227×227×3 input.
///
/// conv1 11×11/s4 → pool → conv2 5×5 (pad 2) → pool → conv3..5 3×3 →
/// pool → fc6 as 6×6 conv → fc7/fc8 as 1×1 convs → softmax.
/// fc8 has no ReLU — it uses the `skip_relu` command extension.
pub fn alexnet() -> Network {
    let mut n = Network::new("alexnet");
    let inp = n.input(227, 3);

    let conv1 = n.engine(LayerSpec::conv("conv1", 11, 4, 0, 227, 3, 96, 0), inp); // 55
    let pool1 = n.engine(LayerSpec::maxpool("pool1", 3, 2, 55, 96), conv1); // 27
    let conv2 = n.engine(LayerSpec::conv("conv2", 5, 1, 2, 27, 96, 256, 0), pool1); // 27
    let pool2 = n.engine(LayerSpec::maxpool("pool2", 3, 2, 27, 256), conv2); // 13
    let conv3 = n.engine(LayerSpec::conv("conv3", 3, 1, 1, 13, 256, 384, 0), pool2);
    let conv4 = n.engine(LayerSpec::conv("conv4", 3, 1, 1, 13, 384, 384, 0), conv3);
    let conv5 = n.engine(LayerSpec::conv("conv5", 3, 1, 1, 13, 384, 256, 0), conv4);
    let pool5 = n.engine(LayerSpec::maxpool("pool5", 3, 2, 13, 256), conv5); // 6

    // FC layers as convolutions. The classic AlexNet has 4096-wide FC
    // layers; we keep the structure but narrow them to stay inside the
    // weight-cache budget per pass — the driver re-slices output channel
    // groups anyway, so this is a capacity choice, not an architecture one.
    let fc6 = n.engine(LayerSpec::conv("fc6", 6, 1, 0, 6, 256, 512, 0), pool5); // 1×1×512
    let fc7 = n.engine(LayerSpec::conv("fc7", 1, 1, 0, 1, 512, 512, 0), fc6);
    let mut fc8_spec = LayerSpec::conv("fc8", 1, 1, 0, 1, 512, 1000, 0);
    fc8_spec.skip_relu = true;
    let fc8 = n.engine(fc8_spec, fc7);
    n.softmax("prob", fc8);
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_check_out() {
        let n = alexnet();
        n.check().unwrap();
        assert_eq!(n.out_shape(n.find("conv1").unwrap()), (55, 96));
        assert_eq!(n.out_shape(n.find("pool5").unwrap()), (6, 256));
        assert_eq!(n.out_shape(n.find("fc8").unwrap()), (1, 1000));
    }

    #[test]
    fn fc8_skips_relu_via_extension_bit() {
        let n = alexnet();
        let specs = n.engine_layers();
        let fc8 = specs.iter().find(|s| s.name == "fc8").unwrap();
        assert!(fc8.skip_relu);
        let d = fc8.encode();
        assert_eq!(d[0] & 0xF, 0x9); // conv(1) | skip_relu(8)
        let back = super::super::layer::LayerSpec::decode("fc8", d).unwrap();
        assert!(back.skip_relu);
    }

    #[test]
    fn alexnet_macs_exceed_squeezenet() {
        // The 11×11 conv1 and 5×5 conv2 dominate; AlexNet has far more
        // MACs than SqueezeNet (the motivation for SqueezeNet, §4.1).
        let a = alexnet().total_macs();
        let s = crate::net::squeezenet::squeezenet_v11().total_macs();
        assert!(a > s, "alexnet {a} vs squeezenet {s}");
    }
}
