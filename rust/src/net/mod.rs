//! CNN network substrate: tensors, layer specs + 96-bit commands, the
//! inference DAG, SqueezeNet v1.1 / AlexNet builders, a Caffe prototxt
//! front-end, and the FAWB weight container shared with Python.

pub mod alexnet;
pub mod googlenet;
pub mod graph;
pub mod layer;
pub mod prototxt;
pub mod squeezenet;
pub mod tensor;
pub mod weights;

pub use graph::{Network, Node};
pub use layer::{LayerSpec, OpType};
pub use tensor::{ConvWeights, Tensor, TensorF16, TensorF32};
pub use weights::Blobs;
