//! Weight/tensor container ("FAWB" format) shared with the Python side.
//!
//! The paper extracts FP32 weights from a caffemodel into an `.npz`
//! (extract.py, Fig 29) which the host script consumes. We use a simpler
//! self-describing binary container written by `python/compile/aot.py`
//! and read here — no numpy dependency on the request path.
//!
//! Layout (little endian):
//! ```text
//! magic  b"FAWB"            (4 bytes)
//! count  u32                number of tensors
//! per tensor:
//!   name_len u16, name bytes (utf-8)
//!   ndim u8, dims u32 × ndim
//!   data f32 × prod(dims)
//! ```
//!
//! Convolution weights are stored in **OHWI** layout
//! (`[o_ch][ky][kx][i_ch]`) to line up with the NHWC activation layout
//! (§3.4.1); biases as 1-D `[o_ch]`.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::graph::{Network, Node};
use super::layer::OpType;
use super::tensor::ConvWeights;
use crate::prop::Rng;

/// A named tensor bundle.
#[derive(Clone, Debug, Default)]
pub struct Blobs {
    pub tensors: BTreeMap<String, (Vec<u32>, Vec<f32>)>,
}

impl Blobs {
    pub fn new() -> Blobs {
        Blobs::default()
    }

    pub fn insert(&mut self, name: &str, dims: Vec<u32>, data: Vec<f32>) {
        assert_eq!(dims.iter().product::<u32>() as usize, data.len(), "{name}");
        self.tensors.insert(name.to_string(), (dims, data));
    }

    pub fn get(&self, name: &str) -> Result<(&[u32], &[f32])> {
        let (dims, data) = self
            .tensors
            .get(name)
            .with_context(|| format!("missing tensor {name:?}"))?;
        Ok((dims, data))
    }

    /// Serialize to FAWB bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"FAWB");
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, (dims, data)) in &self.tensors {
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.push(dims.len() as u8);
            for d in dims {
                out.extend_from_slice(&d.to_le_bytes());
            }
            for v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    /// Parse FAWB bytes.
    pub fn from_bytes(bytes: &[u8]) -> Result<Blobs> {
        let mut cur = std::io::Cursor::new(bytes);
        let mut magic = [0u8; 4];
        cur.read_exact(&mut magic)?;
        if &magic != b"FAWB" {
            bail!("bad magic {magic:?}");
        }
        let count = read_u32(&mut cur)?;
        let mut blobs = Blobs::new();
        for _ in 0..count {
            let name_len = read_u16(&mut cur)? as usize;
            let mut name = vec![0u8; name_len];
            cur.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("tensor name not utf-8")?;
            let ndim = read_u8(&mut cur)? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(&mut cur)?);
            }
            let n: usize = dims.iter().product::<u32>() as usize;
            let mut data = vec![0f32; n];
            let mut buf = vec![0u8; n * 4];
            cur.read_exact(&mut buf)?;
            for (i, chunk) in buf.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            blobs.insert(&name, dims, data);
        }
        Ok(blobs)
    }

    pub fn load(path: &Path) -> Result<Blobs> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("read {}", path.display()))?;
        Blobs::from_bytes(&bytes)
    }

    /// Extract the conv weights + bias for an engine layer. Names follow
    /// the `<layer>_w` / `<layer>_b` convention (slashes kept).
    pub fn conv_weights(&self, layer: &str, k: usize, i_ch: usize, o_ch: usize) -> Result<ConvWeights> {
        let (wd, w) = self.get(&format!("{layer}_w"))?;
        let (bd, b) = self.get(&format!("{layer}_b"))?;
        if wd != [o_ch as u32, k as u32, k as u32, i_ch as u32] {
            bail!("{layer}: weight dims {wd:?} != OHWI [{o_ch},{k},{k},{i_ch}]");
        }
        if bd != [o_ch as u32] {
            bail!("{layer}: bias dims {bd:?}");
        }
        Ok(ConvWeights { o_ch, k, i_ch, data: w.to_vec(), bias: b.to_vec() })
    }
}

fn read_u8(cur: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    cur.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_u16(cur: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    cur.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(cur: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    cur.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Generate deterministic synthetic weights for every conv layer of a
/// network (He-scaled normals). Substitutes for the pre-trained
/// caffemodel (DESIGN.md §3) — the identity-with-oracle claim is about
/// dataflow and rounding, not the particular weight values.
pub fn synthesize_weights(net: &Network, seed: u64) -> Blobs {
    let mut blobs = Blobs::new();
    let mut rng = Rng::new(seed);
    for node in &net.nodes {
        if let Node::Engine { spec, .. } = node {
            if spec.op != OpType::ConvRelu {
                continue;
            }
            let (k, ic, oc) = (spec.kernel as usize, spec.i_ch as usize, spec.o_ch as usize);
            let fan_in = (k * k * ic) as f32;
            let sd = (2.0 / fan_in).sqrt();
            let n = oc * k * k * ic;
            let w: Vec<f32> = (0..n).map(|_| rng.normal(sd)).collect();
            let b: Vec<f32> = (0..oc).map(|_| rng.normal(0.05)).collect();
            blobs.insert(
                &format!("{}_w", spec.name),
                vec![oc as u32, k as u32, k as u32, ic as u32],
                w,
            );
            blobs.insert(&format!("{}_b", spec.name), vec![oc as u32], b);
        }
    }
    blobs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::squeezenet::squeezenet_v11;

    #[test]
    fn roundtrip_bytes() {
        let mut b = Blobs::new();
        b.insert("a_w", vec![2, 1, 1, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        b.insert("a_b", vec![2], vec![0.5, -0.5]);
        let bytes = b.to_bytes();
        let back = Blobs::from_bytes(&bytes).unwrap();
        assert_eq!(back.get("a_w").unwrap().1, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(back.get("a_b").unwrap().0, &[2]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(Blobs::from_bytes(b"NOPE\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn truncated_file_is_rejected() {
        let mut b = Blobs::new();
        b.insert("t_w", vec![4, 1, 1, 4], vec![1.0; 16]);
        let bytes = b.to_bytes();
        for cut in [5, 10, bytes.len() - 1] {
            assert!(Blobs::from_bytes(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn conv_weights_extraction_validates_dims() {
        let mut b = Blobs::new();
        b.insert("c_w", vec![2, 3, 3, 4], vec![0.0; 72]);
        b.insert("c_b", vec![2], vec![0.0; 2]);
        assert!(b.conv_weights("c", 3, 4, 2).is_ok());
        assert!(b.conv_weights("c", 3, 4, 3).is_err()); // wrong o_ch
        assert!(b.conv_weights("missing", 3, 4, 2).is_err());
    }

    #[test]
    fn synthesized_weights_cover_all_convs() {
        let net = squeezenet_v11();
        let blobs = synthesize_weights(&net, 1);
        // 26 convs × 2 tensors (w + b).
        assert_eq!(blobs.tensors.len(), 26 * 2);
        let (dims, w) = blobs.get("conv1_w").unwrap();
        assert_eq!(dims, &[64, 3, 3, 3]);
        // He init: values are small and not all identical.
        assert!(w.iter().all(|v| v.abs() < 3.0));
        assert!(w.iter().any(|v| *v != w[0]));
    }

    #[test]
    fn synthesis_is_deterministic() {
        let net = squeezenet_v11();
        let a = synthesize_weights(&net, 7);
        let b = synthesize_weights(&net, 7);
        let c = synthesize_weights(&net, 8);
        assert_eq!(a.get("conv10_w").unwrap().1, b.get("conv10_w").unwrap().1);
        assert_ne!(a.get("conv10_w").unwrap().1, c.get("conv10_w").unwrap().1);
    }
}
