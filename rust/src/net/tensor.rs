//! NHWC tensors (batch = 1, so effectively HWC) — the storage layout the
//! paper picks in §3.4.1: input channel is the lowest dimension so that a
//! 128-bit BRAM word holds 8 consecutive FP16 channels, which is what the
//! 8 parallel lanes consume each cycle.

use crate::fp16::F16;

/// A dense H×W×C tensor over element type `T`, row-major with channels
/// innermost (NHWC with N=1).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor<T> {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<T>,
}

pub type TensorF32 = Tensor<f32>;
pub type TensorF16 = Tensor<F16>;

impl<T: Copy + Default> Tensor<T> {
    pub fn zeros(h: usize, w: usize, c: usize) -> Tensor<T> {
        Tensor { h, w, c, data: vec![T::default(); h * w * c] }
    }

    pub fn from_vec(h: usize, w: usize, c: usize, data: Vec<T>) -> Tensor<T> {
        assert_eq!(data.len(), h * w * c, "tensor shape/data mismatch");
        Tensor { h, w, c, data }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn idx(&self, y: usize, x: usize, ch: usize) -> usize {
        debug_assert!(y < self.h && x < self.w && ch < self.c);
        (y * self.w + x) * self.c + ch
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> T {
        self.data[self.idx(y, x, ch)]
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: T) {
        let i = self.idx(y, x, ch);
        self.data[i] = v;
    }

    /// Channel-concatenate (the host-side Concat of fire modules; §4.1 —
    /// "Concatenation layers can be realized by Numpy matrix operations").
    pub fn concat_channels(parts: &[&Tensor<T>]) -> Tensor<T> {
        assert!(!parts.is_empty());
        let (h, w) = (parts[0].h, parts[0].w);
        for p in parts {
            assert_eq!((p.h, p.w), (h, w), "concat surface mismatch");
        }
        let c: usize = parts.iter().map(|p| p.c).sum();
        let mut out = Tensor::zeros(h, w, c);
        for y in 0..h {
            for x in 0..w {
                let mut co = 0;
                for p in parts {
                    for ch in 0..p.c {
                        out.set(y, x, co, p.get(y, x, ch));
                        co += 1;
                    }
                }
            }
        }
        out
    }

    /// Zero-pad the surface by `pad` on every side (the pre-padding the
    /// host does before slicing GEMM blocks; Fig 16 discussion).
    pub fn pad_surface(&self, pad: usize) -> Tensor<T> {
        if pad == 0 {
            return self.clone();
        }
        let mut out = Tensor::zeros(self.h + 2 * pad, self.w + 2 * pad, self.c);
        for y in 0..self.h {
            for x in 0..self.w {
                for ch in 0..self.c {
                    out.set(y + pad, x + pad, ch, self.get(y, x, ch));
                }
            }
        }
        out
    }

    /// Pad the channel dimension up to a multiple of `lane` with zeros
    /// (§3.4.3: "we do not need to consider padding 0 in the input channel
    /// dimension except the initial layer whose channel is 3").
    pub fn pad_channels_to(&self, lane: usize) -> Tensor<T> {
        let cp = self.c.div_ceil(lane) * lane;
        if cp == self.c {
            return self.clone();
        }
        let mut out = Tensor::zeros(self.h, self.w, cp);
        for y in 0..self.h {
            for x in 0..self.w {
                for ch in 0..self.c {
                    out.set(y, x, ch, self.get(y, x, ch));
                }
            }
        }
        out
    }

    /// Drop channels above `c` (undo lane padding).
    pub fn truncate_channels(&self, c: usize) -> Tensor<T> {
        assert!(c <= self.c);
        let mut out = Tensor::zeros(self.h, self.w, c);
        for y in 0..self.h {
            for x in 0..self.w {
                for ch in 0..c {
                    out.set(y, x, ch, self.get(y, x, ch));
                }
            }
        }
        out
    }
}

impl TensorF32 {
    /// Quantize to FP16 (one rounding per element) — what happens when the
    /// host loads FP32 blobs onto the FP16 device.
    pub fn to_f16(&self) -> TensorF16 {
        Tensor {
            h: self.h,
            w: self.w,
            c: self.c,
            data: self.data.iter().map(|&x| F16::from_f32(x)).collect(),
        }
    }
}

impl TensorF16 {
    /// Widen to FP32 (exact).
    pub fn to_f32(&self) -> TensorF32 {
        Tensor {
            h: self.h,
            w: self.w,
            c: self.c,
            data: self.data.iter().map(|x| x.to_f32()).collect(),
        }
    }

    /// Max absolute difference vs an f32 tensor (for oracle comparisons).
    pub fn max_abs_diff(&self, other: &TensorF32) -> f32 {
        assert_eq!(self.data.len(), other.data.len());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a.to_f32() - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Convolution weights in O-H-W-I layout: `[o_ch][ky][kx][i_ch]`, matching
/// the NHWC data layout so the 8-lane channel groups line up.
#[derive(Clone, Debug)]
pub struct ConvWeights {
    pub o_ch: usize,
    pub k: usize,
    pub i_ch: usize,
    /// len = o_ch * k * k * i_ch
    pub data: Vec<f32>,
    /// len = o_ch
    pub bias: Vec<f32>,
}

impl ConvWeights {
    pub fn zeros(o_ch: usize, k: usize, i_ch: usize) -> ConvWeights {
        ConvWeights {
            o_ch,
            k,
            i_ch,
            data: vec![0.0; o_ch * k * k * i_ch],
            bias: vec![0.0; o_ch],
        }
    }

    #[inline]
    pub fn idx(&self, oc: usize, ky: usize, kx: usize, ic: usize) -> usize {
        ((oc * self.k + ky) * self.k + kx) * self.i_ch + ic
    }

    #[inline]
    pub fn get(&self, oc: usize, ky: usize, kx: usize, ic: usize) -> f32 {
        self.data[self.idx(oc, ky, kx, ic)]
    }

    pub fn set(&mut self, oc: usize, ky: usize, kx: usize, ic: usize, v: f32) {
        let i = self.idx(oc, ky, kx, ic);
        self.data[i] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut t: TensorF32 = Tensor::zeros(3, 4, 5);
        t.set(2, 3, 4, 9.0);
        assert_eq!(t.get(2, 3, 4), 9.0);
        assert_eq!(t.idx(0, 0, 1), 1); // channels innermost
        assert_eq!(t.idx(0, 1, 0), 5);
        assert_eq!(t.idx(1, 0, 0), 20);
    }

    #[test]
    fn concat_matches_channel_order() {
        let mut a: TensorF32 = Tensor::zeros(2, 2, 1);
        let mut b: TensorF32 = Tensor::zeros(2, 2, 2);
        a.set(1, 1, 0, 1.0);
        b.set(1, 1, 1, 2.0);
        let c = Tensor::concat_channels(&[&a, &b]);
        assert_eq!(c.c, 3);
        assert_eq!(c.get(1, 1, 0), 1.0);
        assert_eq!(c.get(1, 1, 2), 2.0);
    }

    #[test]
    fn pad_surface_places_interior() {
        let mut t: TensorF32 = Tensor::zeros(2, 2, 1);
        t.set(0, 0, 0, 7.0);
        let p = t.pad_surface(1);
        assert_eq!((p.h, p.w), (4, 4));
        assert_eq!(p.get(1, 1, 0), 7.0);
        assert_eq!(p.get(0, 0, 0), 0.0);
    }

    #[test]
    fn channel_padding_roundtrip() {
        let mut t: TensorF32 = Tensor::zeros(1, 1, 3);
        t.set(0, 0, 2, 5.0);
        let p = t.pad_channels_to(8);
        assert_eq!(p.c, 8);
        assert_eq!(p.get(0, 0, 2), 5.0);
        assert_eq!(p.get(0, 0, 7), 0.0);
        let u = p.truncate_channels(3);
        assert_eq!(u, t);
    }

    #[test]
    fn f16_roundtrip_quantization() {
        let t = TensorF32::from_vec(1, 1, 3, vec![1.0, 0.333333, -2.5]);
        let h = t.to_f16();
        let back = h.to_f32();
        assert_eq!(back.get(0, 0, 0), 1.0);
        assert!((back.get(0, 0, 1) - 0.333333).abs() < 1e-3);
        assert_eq!(back.get(0, 0, 2), -2.5);
    }
}
