//! Network graph: the host-side representation of a CNN to forward.
//!
//! The engine executes only conv+ReLU / max-pool / avg-pool (§4.2); the
//! remaining inference glue — concatenation of parallel fire-module
//! branches, dropout (identity at inference), softmax — runs on the host
//! (§4.1, §5), exactly as in the paper.

use super::layer::{LayerSpec, OpType};

/// A node in the inference DAG. `usize` edges index into `Network::nodes`.
#[derive(Clone, Debug)]
pub enum Node {
    /// Network input: `side × side × ch` image.
    Input { side: u32, ch: u32 },
    /// A layer executed on the accelerator engine.
    Engine { spec: LayerSpec, input: usize },
    /// Host-side channel concatenation (fire-module merge).
    Concat { name: String, inputs: Vec<usize> },
    /// Host-side softmax over a 1×1×C tensor.
    Softmax { name: String, input: usize },
    /// A standalone ReLU. The engine has no ReLU op — it only *fuses*
    /// ReLU into convolutions (§3.2) — so this node either runs on the
    /// host or, preferably, is fused/folded away by the command-stream
    /// compiler ([`crate::compiler`]). Front-ends emit it when an
    /// activation cannot be attached to its producer at build time.
    Relu { name: String, input: usize },
}

impl Node {
    /// Indices of the nodes this node reads from.
    pub fn inputs(&self) -> Vec<usize> {
        match self {
            Node::Input { .. } => Vec::new(),
            Node::Engine { input, .. } => vec![*input],
            Node::Concat { inputs, .. } => inputs.clone(),
            Node::Softmax { input, .. } => vec![*input],
            Node::Relu { input, .. } => vec![*input],
        }
    }
}

/// An inference network: DAG of nodes, topologically ordered by
/// construction (every edge points backwards).
#[derive(Clone, Debug, Default)]
pub struct Network {
    pub name: String,
    pub nodes: Vec<Node>,
}

impl Network {
    pub fn new(name: &str) -> Network {
        Network { name: name.to_string(), nodes: Vec::new() }
    }

    pub fn input(&mut self, side: u32, ch: u32) -> usize {
        self.push(Node::Input { side, ch })
    }

    pub fn engine(&mut self, spec: LayerSpec, input: usize) -> usize {
        self.push(Node::Engine { spec, input })
    }

    pub fn concat(&mut self, name: &str, inputs: Vec<usize>) -> usize {
        self.push(Node::Concat { name: name.to_string(), inputs })
    }

    pub fn softmax(&mut self, name: &str, input: usize) -> usize {
        self.push(Node::Softmax { name: name.to_string(), input })
    }

    pub fn relu(&mut self, name: &str, input: usize) -> usize {
        self.push(Node::Relu { name: name.to_string(), input })
    }

    fn push(&mut self, node: Node) -> usize {
        for input in node.inputs() {
            assert!(input < self.nodes.len(), "edge must point backwards");
        }
        self.nodes.push(node);
        self.nodes.len() - 1
    }

    /// (side, channels) produced by node `i`.
    pub fn out_shape(&self, i: usize) -> (u32, u32) {
        match &self.nodes[i] {
            Node::Input { side, ch } => (*side, *ch),
            Node::Engine { spec, .. } => (spec.o_side, spec.o_ch),
            Node::Concat { inputs, .. } => {
                let (side, _) = self.out_shape(inputs[0]);
                let ch = inputs.iter().map(|&j| self.out_shape(j).1).sum();
                (side, ch)
            }
            Node::Softmax { input, .. } => self.out_shape(*input),
            Node::Relu { input, .. } => self.out_shape(*input),
        }
    }

    /// All engine layers in execution order — what gets loaded into
    /// CMDFIFO (§4.4: "theoretically 341 layers are supported").
    pub fn engine_layers(&self) -> Vec<&LayerSpec> {
        self.nodes
            .iter()
            .filter_map(|n| match n {
                Node::Engine { spec, .. } => Some(spec),
                _ => None,
            })
            .collect()
    }

    /// Name of node `i` for reporting.
    pub fn node_name(&self, i: usize) -> &str {
        match &self.nodes[i] {
            Node::Input { .. } => "input",
            Node::Engine { spec, .. } => &spec.name,
            Node::Concat { name, .. } => name,
            Node::Softmax { name, .. } => name,
            Node::Relu { name, .. } => name,
        }
    }

    /// Look up a node index by name.
    pub fn find(&self, name: &str) -> Option<usize> {
        (0..self.nodes.len()).find(|&i| self.node_name(i) == name)
    }

    /// Total multiply-accumulates of all engine conv layers.
    pub fn total_macs(&self) -> u64 {
        self.engine_layers().iter().map(|s| s.macs()).sum()
    }

    /// Total FP16 weights transferred (incl. channel padding + biases).
    pub fn total_weights(&self) -> u64 {
        self.engine_layers().iter().map(|s| s.weight_total()).sum()
    }

    /// Validate shape consistency along every edge.
    pub fn check(&self) -> Result<(), String> {
        for (i, node) in self.nodes.iter().enumerate() {
            match node {
                Node::Input { .. } => {}
                Node::Engine { spec, input } => {
                    let (side, ch) = self.out_shape(*input);
                    if side != spec.i_side {
                        return Err(format!(
                            "{}: input side {} != spec {}",
                            spec.name, side, spec.i_side
                        ));
                    }
                    if ch != spec.i_ch {
                        return Err(format!(
                            "{}: input ch {} != spec {}",
                            spec.name, ch, spec.i_ch
                        ));
                    }
                    match spec.op {
                        OpType::MaxPool | OpType::AvgPool if spec.i_ch != spec.o_ch => {
                            return Err(format!("{}: pooling must keep channels", spec.name));
                        }
                        _ => {}
                    }
                    let _ = i;
                }
                Node::Concat { inputs, name } => {
                    let (side, _) = self.out_shape(inputs[0]);
                    for &j in inputs {
                        if self.out_shape(j).0 != side {
                            return Err(format!("{name}: concat surface mismatch"));
                        }
                    }
                }
                Node::Softmax { .. } => {}
                Node::Relu { .. } => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network {
        let mut n = Network::new("tiny");
        let inp = n.input(8, 3);
        let c1 = n.engine(LayerSpec::conv("c1", 3, 1, 1, 8, 3, 4, 0), inp);
        let e1 = n.engine(LayerSpec::conv("e1", 1, 1, 0, 8, 4, 4, 1), c1);
        let e3 = n.engine(LayerSpec::conv("e3", 3, 1, 1, 8, 4, 4, 5), c1);
        let cat = n.concat("cat", vec![e1, e3]);
        let p = n.engine(LayerSpec::avgpool("gap", 8, 1, 8, 8), cat);
        n.softmax("prob", p);
        n
    }

    #[test]
    fn shapes_propagate() {
        let n = tiny();
        n.check().unwrap();
        let cat = n.find("cat").unwrap();
        assert_eq!(n.out_shape(cat), (8, 8));
        let gap = n.find("gap").unwrap();
        assert_eq!(n.out_shape(gap), (1, 8));
    }

    #[test]
    fn check_catches_bad_edges() {
        let mut n = Network::new("bad");
        let inp = n.input(8, 3);
        n.engine(LayerSpec::conv("c1", 3, 1, 1, 9, 3, 4, 0), inp); // wrong i_side
        assert!(n.check().is_err());
    }

    #[test]
    fn relu_nodes_pass_shapes_through() {
        let mut n = Network::new("r");
        let inp = n.input(8, 3);
        let mut spec = LayerSpec::conv("c1", 3, 1, 1, 8, 3, 4, 0);
        spec.skip_relu = true;
        let c1 = n.engine(spec, inp);
        let r = n.relu("c1_relu", c1);
        n.check().unwrap();
        assert_eq!(n.out_shape(r), (8, 4));
        assert_eq!(n.node_name(r), "c1_relu");
        // Relu is a host node: the engine command stream does not grow.
        assert_eq!(n.engine_layers().len(), 1);
        assert_eq!(n.nodes[r].inputs(), vec![c1]);
    }

    #[test]
    fn engine_layer_enumeration() {
        let n = tiny();
        let names: Vec<_> = n.engine_layers().iter().map(|s| s.name.clone()).collect();
        assert_eq!(names, vec!["c1", "e1", "e3", "gap"]);
        assert!(n.total_macs() > 0);
    }
}
