//! Engine layer descriptors and the 96-bit configuration command encoding
//! (paper Fig 33 + Table 2).
//!
//! The encoding implemented here is the one actually used by the shipped
//! product (reverse-engineered from Table 2's "Command" column), which
//! differs slightly from the draft layout of Fig 33:
//!
//! ```text
//! dword0: [31:24] output_side  [23:16] input_side  [15:8] kernel
//!         [7:4] stride         [3:0] op_type
//! dword1: [31:16] output_channels            [15:0] input_channels
//! dword2: [31:16] stride2 (= stride·kernel)  [15:8] kernel_size (= k²)
//!         [7:4] slot           [3:0] padding
//! ```
//!
//! e.g. conv1 of SqueezeNet v1.1 encodes as `71E3_0321 0040_0003
//! 0006_0900` — o=0x71=113, i=0xE3=227, k=3, s=2, op=1(conv);
//! o_ch=64, i_ch=3; stride2=6, kernel_size=9, slot=0, pad=0 — exactly the
//! Table 2 row. Fig 33's 3-bit op codes (001/100/101) are the draft; the
//! product uses 1=conv, 2=maxpool, 3=avgpool.
//!
//! **Extension** (documented deviation): bit 3 of the op nibble is spare
//! in the paper; we use it as a `skip_relu` flag so networks whose final
//! convolution has no activation (e.g. AlexNet fc8) run on the engine
//! without a host-side fixup. All paper commands have this bit 0.

/// Engine operation selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpType {
    Idle,
    /// Convolution fused with ReLU (§3.2: ReLU is a sign-bit test).
    ConvRelu,
    MaxPool,
    AvgPool,
}

impl OpType {
    pub fn code(self) -> u32 {
        match self {
            OpType::Idle => 0,
            OpType::ConvRelu => 1,
            OpType::MaxPool => 2,
            OpType::AvgPool => 3,
        }
    }

    pub fn from_code(c: u32) -> Option<OpType> {
        Some(match c {
            0 => OpType::Idle,
            1 => OpType::ConvRelu,
            2 => OpType::MaxPool,
            3 => OpType::AvgPool,
            _ => return None,
        })
    }
}

/// Parameters of a single engine layer — the information carried by one
/// 12-byte command (Fig 33), plus the layer name for reporting.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerSpec {
    pub name: String,
    pub op: OpType,
    pub kernel: u32,
    pub stride: u32,
    /// Symmetric zero padding applied by the host before slicing.
    pub padding: u32,
    pub i_side: u32,
    pub o_side: u32,
    pub i_ch: u32,
    pub o_ch: u32,
    /// Parallel-layer tag (§4.4): bits [1:0] = position among parallel
    /// layers, bits [3:2] = number of parallel siblings. 0 for sequential
    /// layers, 1/5 for the expand1x1/expand3x3 pair of a fire module.
    pub slot: u32,
    /// Extension: suppress the fused ReLU (see module docs).
    pub skip_relu: bool,
}

impl LayerSpec {
    pub fn conv(
        name: &str,
        kernel: u32,
        stride: u32,
        padding: u32,
        i_side: u32,
        i_ch: u32,
        o_ch: u32,
        slot: u32,
    ) -> LayerSpec {
        let o_side = (i_side + 2 * padding - kernel) / stride + 1;
        LayerSpec {
            name: name.to_string(),
            op: OpType::ConvRelu,
            kernel,
            stride,
            padding,
            i_side,
            o_side,
            i_ch,
            o_ch,
            slot,
            skip_relu: false,
        }
    }

    /// Max-pooling layer. `o_side` follows Caffe's ceil mode — windows may
    /// overhang the bottom/right border and are clipped (§4.1's pool3/pool5
    /// "padding layers" in Table 1 are exactly this overhang).
    pub fn maxpool(name: &str, kernel: u32, stride: u32, i_side: u32, ch: u32) -> LayerSpec {
        let o_side = (i_side - kernel).div_ceil(stride) + 1;
        LayerSpec {
            name: name.to_string(),
            op: OpType::MaxPool,
            kernel,
            stride,
            padding: 0,
            i_side,
            o_side,
            i_ch: ch,
            o_ch: ch,
            slot: 0,
            skip_relu: false,
        }
    }

    /// Max-pooling with symmetric padding — needed by GoogLeNet's
    /// inception pool branches (3×3/s1/p1 "same" pooling). Padding is
    /// virtual: windows are clipped on all four sides, which for max is
    /// equivalent to -inf padding (and interacts with the RTL's 0x0000
    /// comparator init exactly like border clipping does).
    pub fn maxpool_padded(
        name: &str,
        kernel: u32,
        stride: u32,
        padding: u32,
        i_side: u32,
        ch: u32,
    ) -> LayerSpec {
        let o_side = (i_side + 2 * padding - kernel).div_ceil(stride) + 1;
        LayerSpec { padding, ..LayerSpec::maxpool(name, kernel, stride, i_side, ch) }
            .with_o_side(o_side)
    }

    fn with_o_side(mut self, o: u32) -> LayerSpec {
        self.o_side = o;
        self
    }

    pub fn avgpool(name: &str, kernel: u32, stride: u32, i_side: u32, ch: u32) -> LayerSpec {
        let o_side = (i_side - kernel) / stride + 1;
        LayerSpec {
            name: name.to_string(),
            op: OpType::AvgPool,
            kernel,
            stride,
            padding: 0,
            i_side,
            o_side,
            i_ch: ch,
            o_ch: ch,
            slot: 0,
            skip_relu: false,
        }
    }

    /// `kernel_size` field value (k², precomputed host-side to save an
    /// on-chip integer multiplier — §4.4).
    pub fn kernel_size(&self) -> u32 {
        self.kernel * self.kernel
    }

    /// `stride2` field value (stride·kernel — §4.4).
    pub fn stride2(&self) -> u32 {
        self.stride * self.kernel
    }

    /// Number of output elements (Table 2 "size" column).
    pub fn output_elems(&self) -> u64 {
        self.o_side as u64 * self.o_side as u64 * self.o_ch as u64
    }

    /// Number of multiply-accumulates this layer performs (conv only).
    pub fn macs(&self) -> u64 {
        match self.op {
            OpType::ConvRelu => {
                self.output_elems() * self.kernel_size() as u64 * self.i_ch as u64
            }
            _ => 0,
        }
    }

    /// Total FP16 weight values incl. bias that the host transfers
    /// (Table 2 "total" column). The input channel count is padded to the
    /// lane width (8): conv1's 3 channels become 8, giving 9·8·64+64 =
    /// 4672 exactly as in the table.
    pub fn weight_total(&self) -> u64 {
        match self.op {
            OpType::ConvRelu => {
                let ic_padded = (self.i_ch as u64).div_ceil(8) * 8;
                self.kernel_size() as u64 * ic_padded * self.o_ch as u64 + self.o_ch as u64
            }
            _ => 0,
        }
    }

    /// Encode to the three command dwords.
    pub fn encode(&self) -> [u32; 3] {
        assert!(self.o_side < 256 && self.i_side < 256, "side field is 8 bits");
        assert!(self.kernel < 256 && self.stride < 16 && self.padding < 16);
        assert!(
            self.kernel_size() < 256 && self.stride2() < 65536,
            "{}: kernel {} overflows the 8-bit kernel_size field (max 15)",
            self.name,
            self.kernel
        );
        assert!(self.i_ch < 65536 && self.o_ch < 65536 && self.slot < 16);
        let op = self.op.code() | if self.skip_relu { 0x8 } else { 0 };
        [
            (self.o_side << 24) | (self.i_side << 16) | (self.kernel << 8) | (self.stride << 4) | op,
            (self.o_ch << 16) | self.i_ch,
            (self.stride2() << 16) | (self.kernel_size() << 8) | (self.slot << 4) | self.padding,
        ]
    }

    /// Decode from the three command dwords (what the CSB does — §4.1).
    pub fn decode(name: &str, d: [u32; 3]) -> Option<LayerSpec> {
        let op_raw = d[0] & 0xF;
        let op = OpType::from_code(op_raw & 0x7)?;
        let spec = LayerSpec {
            name: name.to_string(),
            op,
            kernel: (d[0] >> 8) & 0xFF,
            stride: (d[0] >> 4) & 0xF,
            padding: d[2] & 0xF,
            i_side: (d[0] >> 16) & 0xFF,
            o_side: (d[0] >> 24) & 0xFF,
            i_ch: d[1] & 0xFFFF,
            o_ch: (d[1] >> 16) & 0xFFFF,
            slot: (d[2] >> 4) & 0xF,
            skip_relu: op_raw & 0x8 != 0,
        };
        // Validate the redundant precomputed fields.
        if (d[2] >> 16) != spec.stride2() || ((d[2] >> 8) & 0xFF) != spec.kernel_size() {
            return None;
        }
        Some(spec)
    }

    /// Render the command like Table 2's hex column, e.g.
    /// `71E3_0321 0040_0003 0006_0900`.
    pub fn command_hex(&self) -> String {
        let d = self.encode();
        format!(
            "{:04X}_{:04X} {:04X}_{:04X} {:04X}_{:04X}",
            d[0] >> 16,
            d[0] & 0xFFFF,
            d[1] >> 16,
            d[1] & 0xFFFF,
            d[2] >> 16,
            d[2] & 0xFFFF
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv1_matches_table2() {
        let conv1 = LayerSpec::conv("conv1", 3, 2, 0, 227, 3, 64, 0);
        assert_eq!(conv1.o_side, 113);
        assert_eq!(conv1.command_hex(), "71E3_0321 0040_0003 0006_0900");
    }

    #[test]
    fn pool1_matches_table2() {
        let pool1 = LayerSpec::maxpool("pool1", 3, 2, 113, 64);
        assert_eq!(pool1.o_side, 56);
        assert_eq!(pool1.command_hex(), "3871_0322 0040_0040 0006_0900");
    }

    #[test]
    fn expand3x3_matches_table2() {
        let e = LayerSpec::conv("fire2/expand3x3", 3, 1, 1, 56, 16, 64, 5);
        assert_eq!(e.o_side, 56);
        assert_eq!(e.command_hex(), "3838_0311 0040_0010 0003_0951");
    }

    #[test]
    fn pool10_matches_table2() {
        let p = LayerSpec::avgpool("pool10", 14, 1, 14, 1000);
        assert_eq!(p.o_side, 1);
        assert_eq!(p.command_hex(), "010E_0E13 03E8_03E8 000E_C400");
    }

    #[test]
    fn ceil_mode_pooling_sides() {
        // pool3: 56 → 28 and pool5: 28 → 14 need ceil mode (Table 2).
        assert_eq!(LayerSpec::maxpool("pool3", 3, 2, 56, 128).o_side, 28);
        assert_eq!(LayerSpec::maxpool("pool5", 3, 2, 28, 256).o_side, 14);
        // pool1: exact division, same under floor and ceil.
        assert_eq!(LayerSpec::maxpool("pool1", 3, 2, 113, 64).o_side, 56);
    }

    #[test]
    fn encode_decode_roundtrip() {
        crate::prop::forall(
            0xC0DE,
            2000,
            |r| {
                let kernel = *r.choose(&[1u32, 3, 5, 7, 11, 14]);
                let stride = r.range(1, 4) as u32;
                let i_side = r.range(kernel as i64, 255) as u32;
                let mut s = LayerSpec::conv(
                    "t",
                    kernel,
                    stride,
                    r.range(0, 3) as u32,
                    i_side,
                    r.range(1, 4096) as u32,
                    r.range(1, 4096) as u32,
                    r.range(0, 15) as u32,
                );
                s.skip_relu = r.chance(0.3);
                match r.below(3) {
                    0 => {
                        s.op = OpType::MaxPool;
                        s.padding = 0;
                    }
                    1 => {
                        s.op = OpType::AvgPool;
                        s.padding = 0;
                    }
                    _ => {}
                }
                s
            },
            |s| {
                if s.o_side >= 256 {
                    return Ok(()); // out of field range, skip
                }
                let d = s.encode();
                let back = LayerSpec::decode("t", d)
                    .ok_or_else(|| "decode failed".to_string())?;
                if back == *s {
                    Ok(())
                } else {
                    Err(format!("roundtrip mismatch: {back:?}"))
                }
            },
        );
    }

    #[test]
    fn decode_rejects_bad_derived_fields() {
        let s = LayerSpec::conv("x", 3, 2, 0, 227, 3, 64, 0);
        let mut d = s.encode();
        d[2] ^= 0x0001_0000; // corrupt stride2
        assert!(LayerSpec::decode("x", d).is_none());
    }

    #[test]
    fn macs_and_weight_totals() {
        let conv1 = LayerSpec::conv("conv1", 3, 2, 0, 227, 3, 64, 0);
        assert_eq!(conv1.output_elems(), 113 * 113 * 64);
        assert_eq!(conv1.weight_total(), 4672); // Table 2 "total": 9·8·64 + 64
        let sq = LayerSpec::conv("fire2/squeeze1x1", 1, 1, 0, 56, 64, 16, 0);
        assert_eq!(sq.weight_total(), 1040); // Table 2: 1·64·16 + 16
    }
}
