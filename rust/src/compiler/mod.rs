//! Command-stream compiler: lower [`Network`] graphs into optimized,
//! cacheable CSB artifacts.
//!
//! The paper's headline claim is runtime re-configurability — the CSB
//! re-parses a 12-byte command per layer, so swapping networks is just
//! swapping command streams (§4.1, §4.4). This module is the layer that
//! turns that mechanism into a serving feature:
//!
//! 1. **Passes** ([`passes`]) — a fixpoint pipeline over the graph:
//!    conv+ReLU fusion and pool/ReLU folding into single `LayerSpec`
//!    commands where the datapath supports it, `Idle` stripping, and
//!    dead-node elimination. Every pass is bit-preserving on the
//!    network output.
//! 2. **Artifacts** ([`artifact`]) — the pass output is scheduled into
//!    CMDFIFO-sized *reload epochs* (networks deeper than the
//!    341-command FIFO reload mid-forward instead of failing) and
//!    content-addressed by a fingerprint of the optimized graph plus
//!    the weights identity.
//! 3. **Registry** ([`registry`]) — compiles are memoized per source
//!    graph + weights; [`registry::ModelRepo`] holds the named model
//!    set a multi-network worker pool serves from, and the device-side
//!    command shadow
//!    ([`crate::accel::stream::StreamAccelerator::load_commands_cached`])
//!    keyed by artifact id makes command transfers happen only on a
//!    network *switch*.
//! 4. **Cost & layout** ([`cost`], [`layout`]) — an oracle traffic
//!    model predicts the *exact* per-layer engine passes, weight-cache
//!    loads, and link bytes of a compiled stream for every candidate
//!    granularity and batch size (pinned `modeled == measured` by
//!    property tests); the layout pass picks the argmin-modeled-cost
//!    granularity per conv, and the modeled cost rides on the artifact
//!    so the serving tier can price cold networks before any request
//!    has run.
//! 5. **Verification** ([`verify`]) — a static analyzer walks every
//!    compiled artifact with an abstract machine model and proves the
//!    hardware invariants (cache bounds, epoch tiling, RESFIFO safety,
//!    the channel-split partial-bias protocol, cost-model consistency)
//!    or returns typed violations with stable error codes. [`compile`]
//!    rejects violating artifacts and stamps a verification seal;
//!    [`registry::ModelRepo::serveable`] refuses unsealed or stale
//!    artifacts; `fusionaccel lint` prints the report.
//!
//! Execution of compiled streams lives with the drivers:
//! [`crate::host::driver::HostDriver::forward_compiled`] and
//! [`crate::host::batch::forward_batch_compiled`].
//!
//! [`Network`]: crate::net::graph::Network

pub mod artifact;
pub mod cache;
pub mod cost;
pub mod layout;
pub mod passes;
pub mod registry;
pub mod verify;

pub use artifact::{compile, compile_unverified, fnv1a, graph_fingerprint, CompiledStream, EpochPlan};
pub use cache::LruCache;
pub use cost::{conv_layer_cost, stream_cost, LayerCost, Residency, StreamCost};
pub use layout::{legal_granularities, plan_granularities, plan_granularities_with};
pub use passes::{run_pipeline, PassReport};
pub use registry::{ArtifactRegistry, ModelRepo, ServableModel};
pub use verify::{artifact_seal, verify, verify_sealed, Severity, VerifyReport, Violation};
