//! Compiled command-stream artifacts: content-addressed, validated,
//! epoch-scheduled lowerings of a [`Network`].
//!
//! An artifact is what the serving stack actually distributes: the
//! optimized graph (after the [`super::passes`] pipeline), the command
//! stream split into CMDFIFO-sized **reload epochs**, and an id derived
//! from the optimized graph plus the weights identity — so two
//! front-ends that describe the same computation (builder vs prototxt)
//! produce the *same* artifact, and a worker can tell "same network,
//! skip the command transfer" apart from "new network, reconfigure"
//! by comparing ids alone (§4.1's re-configurability made cacheable).

use anyhow::Result;

use crate::engine::csb::{CMD_BURST_LEN, CMDFIFO_DEPTH, MAX_LAYERS};
use crate::host::gemm::{ConvGranularity, WeightPlan};
use crate::net::graph::{Network, Node};
use crate::net::layer::LayerSpec;

use super::cost;
use super::layout;
use super::passes::{self, PassReport};
use super::verify::{self, SplitPlan};

/// FNV-1a 64-bit over a byte stream — the artifact fingerprint hash.
/// Chosen for determinism and zero dependencies, not cryptography: ids
/// gate cache reuse, and a stale hit is caught by the CSB's redundant
/// stride2/kernel_size validation at decode time.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Incremental FNV-1a writer for structured fingerprints.
#[derive(Clone, Debug)]
pub struct Fingerprint(u64);

impl Default for Fingerprint {
    fn default() -> Self {
        Fingerprint::new()
    }
}

impl Fingerprint {
    pub fn new() -> Fingerprint {
        Fingerprint(0xcbf2_9ce4_8422_2325)
    }

    pub fn bytes(&mut self, bytes: &[u8]) -> &mut Self {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.bytes(&v.to_le_bytes())
    }

    /// Length-prefixed string (avoids concatenation ambiguity).
    pub fn str(&mut self, s: &str) -> &mut Self {
        self.u64(s.len() as u64).bytes(s.as_bytes())
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Fingerprint of a graph's *computation*: node kinds, edges, layer
/// commands, and engine-layer names (they bind weights), but not the
/// cosmetic names of host nodes or of the network itself — so renaming
/// a concat or the net does not invalidate caches.
pub fn graph_fingerprint(net: &Network) -> u64 {
    let mut h = Fingerprint::new();
    h.bytes(b"fa-graph-v1").u64(net.nodes.len() as u64);
    for node in &net.nodes {
        match node {
            Node::Input { side, ch } => {
                h.u64(0).u64(*side as u64).u64(*ch as u64);
            }
            Node::Engine { spec, input } => {
                h.u64(1).u64(*input as u64).str(&spec.name);
                for d in spec.encode() {
                    h.u64(d as u64);
                }
            }
            Node::Concat { inputs, .. } => {
                h.u64(2).u64(inputs.len() as u64);
                for &i in inputs {
                    h.u64(i as u64);
                }
            }
            Node::Softmax { input, .. } => {
                h.u64(3).u64(*input as u64);
            }
            Node::Relu { input, .. } => {
                h.u64(4).u64(*input as u64);
            }
        }
    }
    h.finish()
}

/// Combine a graph fingerprint with a weights identity into the
/// registry key / artifact id value.
pub fn combine(graph_fp: u64, weights_id: u64) -> u64 {
    let mut h = Fingerprint::new();
    h.bytes(b"fa-artifact-v1").u64(graph_fp).u64(weights_id);
    h.finish()
}

/// One CMDFIFO residency: engine layers `start .. start + len` (indices
/// into the optimized net's `engine_layers()` order) are loaded as one
/// command transfer and fully drained before the next epoch loads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EpochPlan {
    pub start: usize,
    pub len: usize,
}

/// Static schedule: split `n_layers` commands into epochs of at most
/// [`MAX_LAYERS`] (= [`CMDFIFO_DEPTH`] / [`CMD_BURST_LEN`]) so a deep
/// network reloads the CMDFIFO mid-forward instead of overflowing it at
/// runtime (§4.4's "theoretically 341 layers" stops being a hard wall).
pub fn schedule_epochs(n_layers: usize) -> Vec<EpochPlan> {
    debug_assert_eq!(MAX_LAYERS, CMDFIFO_DEPTH / CMD_BURST_LEN);
    let mut epochs = Vec::new();
    let mut start = 0;
    while start < n_layers {
        let len = (n_layers - start).min(MAX_LAYERS);
        epochs.push(EpochPlan { start, len });
        start += len;
    }
    epochs
}

/// A validated, optimized, content-addressed lowering of a network —
/// the unit the [`super::registry`] stores and workers reconfigure
/// from.
#[derive(Clone, Debug)]
pub struct CompiledStream {
    /// Content-addressed artifact id: hex of the optimized-graph
    /// fingerprint combined with the weights id.
    pub id: String,
    /// The optimized graph the driver executes (passes applied; do not
    /// mutate — `epochs` index its engine-layer order).
    pub net: Network,
    /// Identity of the weights this stream was compiled against.
    pub weights_id: u64,
    /// Fingerprint of the *source* graph, pre-optimization (the
    /// registry's memo key component).
    pub source_fingerprint: u64,
    /// CMDFIFO reload schedule over the optimized engine layers.
    pub epochs: Vec<EpochPlan>,
    /// What each pass did (for logs and tests).
    pub report: PassReport,
    /// Cross-batch weight residency plan (fixed weight/bias-cache homes
    /// per conv super-block when the whole net fits; empty otherwise).
    /// Computed once here so the per-request drivers never rebuild it.
    pub weight_plan: WeightPlan,
    /// GEMM slicing granularity per engine layer (the compile-time
    /// layout pass, [`super::layout::plan_granularities`]): `None` for
    /// pool/idle layers. The compiled drivers read this instead of
    /// re-deriving the layout on every forward.
    pub granularities: Vec<Option<ConvGranularity>>,
    /// Oracle-modeled single-image cold cost of this stream
    /// ([`super::cost::model_stream`] at batch 1, [`Residency::Cold`]):
    /// the serving tier's prior for networks with no measured evidence
    /// yet. Other batch sizes / residencies are recomputed on demand
    /// via [`super::cost::stream_cost`].
    ///
    /// [`Residency::Cold`]: super::cost::Residency::Cold
    pub modeled: cost::StreamCost,
    /// The explicit channel-split partial-bias protocol per engine layer
    /// (indexed like `granularities`; `None` for non-split layers). See
    /// [`super::verify::plan_splits`] — recorded on the artifact so the
    /// protocol is statically checkable, not implicit in driver loops.
    pub split_plans: Vec<Option<SplitPlan>>,
    /// Verification seal: [`super::verify::artifact_seal`] of this
    /// artifact's content, stamped by [`compile`] after a clean
    /// [`super::verify::verify`] run. `0` means *unverified* — the
    /// serve-time gate ([`super::registry::ModelRepo::serveable`])
    /// refuses such artifacts, as it does any whose content no longer
    /// matches the stamp.
    pub seal: u64,
}

impl CompiledStream {
    /// Engine layers of epoch `e`, in command order.
    pub fn epoch_layers(&self, e: usize) -> Vec<&LayerSpec> {
        let all = self.net.engine_layers();
        let p = self.epochs[e];
        all[p.start..p.start + p.len].to_vec()
    }

    /// Device cache key for epoch `e`. Single-epoch streams (the common
    /// case) use the bare artifact id so the device shadow survives
    /// across forwards of the same network.
    pub fn epoch_key(&self, e: usize) -> String {
        if self.epochs.len() == 1 {
            self.id.clone()
        } else {
            format!("{}#e{e}", self.id)
        }
    }

    /// Total commands across all epochs.
    pub fn n_commands(&self) -> usize {
        self.epochs.iter().map(|p| p.len).sum()
    }
}

/// Lower `net` into a [`CompiledStream`] *without* verifying it:
/// validate the graph, run the pass pipeline ([`super::passes`]),
/// validate again, schedule epochs, and fingerprint. The result carries
/// `seal == 0` (unverified) — the serving stack will refuse it. This
/// entry point exists for the verifier's own callers (`lint` wants the
/// report even when compilation would be rejected; the mutation harness
/// wants raw artifacts to corrupt); everything else goes through
/// [`compile`].
pub fn compile_unverified(net: &Network, weights_id: u64) -> Result<CompiledStream> {
    net.check().map_err(anyhow::Error::msg)?;
    let source_fingerprint = graph_fingerprint(net);
    let (optimized, report) = passes::run_pipeline(net);
    optimized.check().map_err(anyhow::Error::msg)?;
    let epochs = schedule_epochs(optimized.engine_layers().len());
    let id = format!("{:016x}", combine(graph_fingerprint(&optimized), weights_id));
    let weight_plan = WeightPlan::plan(&id, &optimized.engine_layers());
    let granularities = layout::plan_granularities(&optimized);
    let split_plans = verify::plan_splits(&optimized, &granularities);
    let modeled = cost::model_stream(
        &optimized,
        &epochs,
        weight_plan.is_resident(),
        &granularities,
        1,
        cost::Residency::Cold,
    );
    Ok(CompiledStream {
        id,
        net: optimized,
        weights_id,
        source_fingerprint,
        epochs,
        report,
        weight_plan,
        granularities,
        modeled,
        split_plans,
        seal: 0,
    })
}

/// Lower `net` into a verified [`CompiledStream`]. `weights_id` is the
/// identity of the weight set the stream will run against (see
/// [`super::registry::ModelRepo`], which derives it from the FAWB
/// bytes). The artifact is statically verified ([`super::verify`])
/// before it is returned: any Error-severity finding rejects the
/// compilation, and a clean artifact is stamped with its verification
/// seal so the serving stack can prove later that *this exact content*
/// passed.
pub fn compile(net: &Network, weights_id: u64) -> Result<CompiledStream> {
    let mut cs = compile_unverified(net, weights_id)?;
    let findings = verify::verify(&cs);
    let errors = findings.errors();
    if !errors.is_empty() {
        anyhow::bail!(
            "compiled stream for {:?} fails static verification ({} error(s)):\n{}",
            net.name,
            errors.len(),
            findings.render()
        );
    }
    cs.seal = verify::artifact_seal(&cs);
    Ok(cs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn fingerprint_is_length_prefixed() {
        let mut a = Fingerprint::new();
        a.str("ab").str("c");
        let mut b = Fingerprint::new();
        b.str("a").str("bc");
        assert_ne!(a.finish(), b.finish());
    }

    #[test]
    fn epoch_schedule_covers_exactly() {
        assert!(schedule_epochs(0).is_empty());
        assert_eq!(schedule_epochs(30), vec![EpochPlan { start: 0, len: 30 }]);
        assert_eq!(schedule_epochs(MAX_LAYERS), vec![EpochPlan { start: 0, len: MAX_LAYERS }]);
        let two = schedule_epochs(MAX_LAYERS + 59);
        assert_eq!(
            two,
            vec![
                EpochPlan { start: 0, len: MAX_LAYERS },
                EpochPlan { start: MAX_LAYERS, len: 59 }
            ]
        );
        let big = schedule_epochs(3 * MAX_LAYERS + 1);
        assert_eq!(big.len(), 4);
        assert_eq!(big.iter().map(|p| p.len).sum::<usize>(), 3 * MAX_LAYERS + 1);
        assert!(big.iter().all(|p| p.len <= MAX_LAYERS));
    }

    #[test]
    fn graph_fingerprint_ignores_cosmetic_names() {
        use crate::net::layer::LayerSpec;
        let build = |net_name: &str, cat_name: &str| {
            let mut n = Network::new(net_name);
            let inp = n.input(8, 3);
            let e1 = n.engine(LayerSpec::conv("e1", 1, 1, 0, 8, 3, 4, 1), inp);
            let e3 = n.engine(LayerSpec::conv("e3", 3, 1, 1, 8, 3, 4, 5), inp);
            let cat = n.concat(cat_name, vec![e1, e3]);
            n.softmax("prob", cat);
            n
        };
        assert_eq!(graph_fingerprint(&build("a", "cat")), graph_fingerprint(&build("b", "merge")));
        // …but engine-layer names bind weights and must matter.
        let mut other = build("a", "cat");
        if let Node::Engine { spec, .. } = &mut other.nodes[1] {
            spec.name = "renamed".into();
        }
        assert_ne!(graph_fingerprint(&build("a", "cat")), graph_fingerprint(&other));
    }

    #[test]
    fn compile_rejects_invalid_graphs() {
        let mut n = Network::new("bad");
        let inp = n.input(8, 3);
        n.engine(crate::net::layer::LayerSpec::conv("c", 3, 1, 1, 9, 3, 4, 0), inp);
        assert!(compile(&n, 0).is_err());
    }
}
