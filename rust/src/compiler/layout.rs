//! Compile-time GEMM layout pass: pick each conv layer's slicing
//! granularity (row / pixel / channel-split) once, when the stream is
//! built, instead of per forward.
//!
//! Since the oracle cost model ([`super::cost`]) predicts the exact
//! link traffic of every candidate, the pass is an **argmin**: it
//! enumerates the granularities that are *legal* for the layer (slice
//! fits the data cache) and picks the one with the lowest modeled
//! single-image service time. The old first-fit order (row, then pixel,
//! then channel-split) survives only as the tie-break, so layers where
//! candidates model identically — e.g. a channel split that degenerates
//! to one chunk — keep their historical verdict, and every previously
//! pinned layout stays pinned.
//!
//! The serving hot path (`forward_compiled`, `forward_batch_compiled`)
//! reads [`crate::compiler::CompiledStream::granularities`] and never
//! re-derives the layout. The uncompiled classic flow still computes
//! first-fit on the fly ([`crate::host::gemm::conv_granularity`]); the
//! argmin can only ever pick a *cheaper* legal candidate, and the
//! property tests pin that it never disagrees on today's model zoo.

use crate::accel::stream::DATA_CACHE_WORDS;
use crate::host::gemm::{self, ConvGranularity, DATA_CACHE_VALUES};
use crate::hw::usb::UsbLink;
use crate::net::graph::Network;
use crate::net::layer::{LayerSpec, OpType};

use super::cost;

/// The granularities whose data slices fit the device caches for this
/// layer, in first-fit (tie-break) order.
pub fn legal_granularities(spec: &LayerSpec) -> Vec<ConvGranularity> {
    let k = spec.kernel as usize;
    let icp = (spec.i_ch as usize).div_ceil(8) * 8;
    let pw = (spec.i_side + 2 * spec.padding) as usize;
    let mut out = Vec::with_capacity(3);
    if k * pw * icp <= DATA_CACHE_VALUES {
        out.push(ConvGranularity::Row);
    }
    if k * k * icp <= DATA_CACHE_VALUES {
        out.push(ConvGranularity::Pixel);
    }
    if k * k <= DATA_CACHE_WORDS {
        out.push(ConvGranularity::ChannelSplit);
    }
    out
}

/// Granularity per engine layer (indexed like `net.engine_layers()`);
/// `None` for pool/idle layers, which have no GEMM layout to pick.
/// Convs get the argmin-modeled-cost legal granularity under the
/// default score: modeled single-image seconds over the USB3 link.
pub fn plan_granularities(net: &Network) -> Vec<Option<ConvGranularity>> {
    let usb = UsbLink::usb3_frontpanel();
    plan_granularities_with(net, &|spec, g| cost::conv_layer_cost(spec, g, 1).seconds(&usb))
}

/// Argmin layout with an injectable score (the seam the mis-cost tests
/// use): for each conv, every legal granularity is scored and the
/// cheapest wins; ties keep first-fit order (strict `<` comparison).
/// A layer with no legal candidate falls back to the first-fit verdict
/// unchanged, so failure behavior (a runtime error in the driver) is
/// identical to the old pass.
pub fn plan_granularities_with(
    net: &Network,
    score: &dyn Fn(&LayerSpec, ConvGranularity) -> f64,
) -> Vec<Option<ConvGranularity>> {
    net.engine_layers()
        .iter()
        .map(|spec| {
            (spec.op == OpType::ConvRelu).then(|| {
                let mut best: Option<(ConvGranularity, f64)> = None;
                for g in legal_granularities(spec) {
                    let c = score(spec, g);
                    let better = match best {
                        None => true,
                        Some((_, b)) => c < b,
                    };
                    if better {
                        best = Some((g, c));
                    }
                }
                best.map(|(g, _)| g).unwrap_or_else(|| {
                    let icp = (spec.i_ch as usize).div_ceil(8) * 8;
                    let pw = (spec.i_side + 2 * spec.padding) as usize;
                    gemm::conv_granularity(spec.kernel as usize, pw, icp)
                })
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::alexnet::alexnet;
    use crate::net::squeezenet::squeezenet_v11;

    #[test]
    fn alexnet_layers_span_all_three_granularities() {
        let net = alexnet();
        let layers = net.engine_layers();
        let plan = plan_granularities(&net);
        assert_eq!(plan.len(), layers.len());
        let by_name = |name: &str| {
            let i = layers.iter().position(|s| s.name == name).unwrap();
            plan[i]
        };
        // conv1 11×11: row slice 19976 > cache, pixel 968 fits.
        assert_eq!(by_name("conv1"), Some(ConvGranularity::Pixel));
        // conv3 3×3 over 256 ch at 13+2: row 3·15·256 = 11520 > cache.
        assert_eq!(by_name("conv3"), Some(ConvGranularity::Pixel));
        // fc6 6×6 over 256 ch: one window is 1152 words — channel split.
        assert_eq!(by_name("fc6"), Some(ConvGranularity::ChannelSplit));
        // fc7/fc8 1×1 over 512: row fits (1·1·512 = 512) and models
        // strictly cheaper than pixel (one slice per output row vs per
        // output pixel), so the argmin agrees with first-fit.
        assert_eq!(by_name("fc7"), Some(ConvGranularity::Row));
        // Pool layers have no conv layout.
        assert_eq!(by_name("pool1"), None);
    }

    #[test]
    fn squeezenet_is_all_row() {
        let net = squeezenet_v11();
        for (spec, g) in net.engine_layers().iter().zip(plan_granularities(&net)) {
            match g {
                Some(g) => assert_eq!(g, ConvGranularity::Row, "{}", spec.name),
                None => assert_ne!(spec.op, OpType::ConvRelu),
            }
        }
    }

    #[test]
    fn argmin_agrees_with_first_fit_on_the_model_zoo() {
        // The old first-fit pass picked the cheapest legal candidate on
        // every supported network (row beats pixel whenever legal; a
        // split never beats a legal pixel) — so the argmin rewrite must
        // reproduce it layer for layer.
        for net in [squeezenet_v11(), alexnet()] {
            let first_fit: Vec<Option<ConvGranularity>> = net
                .engine_layers()
                .iter()
                .map(|spec| {
                    (spec.op == OpType::ConvRelu).then(|| {
                        let icp = (spec.i_ch as usize).div_ceil(8) * 8;
                        let pw = (spec.i_side + 2 * spec.padding) as usize;
                        gemm::conv_granularity(spec.kernel as usize, pw, icp)
                    })
                })
                .collect();
            assert_eq!(plan_granularities(&net), first_fit, "{}", net.name);
        }
    }

    #[test]
    fn legality_tracks_cache_arithmetic() {
        // SqueezeNet conv1: everything legal.
        let c1 = LayerSpec::conv("c1", 3, 2, 0, 227, 3, 64, 0);
        assert_eq!(
            legal_granularities(&c1),
            vec![ConvGranularity::Row, ConvGranularity::Pixel, ConvGranularity::ChannelSplit]
        );
        // AlexNet conv1: row slice exceeds the cache.
        let a1 = LayerSpec::conv("a1", 11, 4, 0, 227, 3, 96, 0);
        assert_eq!(
            legal_granularities(&a1),
            vec![ConvGranularity::Pixel, ConvGranularity::ChannelSplit]
        );
        // fc6: only the split is legal.
        let fc6 = LayerSpec::conv("fc6", 6, 1, 0, 6, 256, 4096, 0);
        assert_eq!(legal_granularities(&fc6), vec![ConvGranularity::ChannelSplit]);
    }

    #[test]
    fn mis_costed_candidate_is_never_selected() {
        // Inflate row's score sky-high: the argmin must switch every
        // row-legal conv to its next-cheapest candidate, and a candidate
        // scored infinitely expensive must never win.
        let net = squeezenet_v11();
        let plan = plan_granularities_with(&net, &|spec, g| match g {
            ConvGranularity::Row => f64::INFINITY,
            _ => cost::conv_layer_cost(spec, g, 1).seconds(&UsbLink::usb3_frontpanel()),
        });
        for (spec, g) in net.engine_layers().iter().zip(plan) {
            if let Some(g) = g {
                assert_ne!(g, ConvGranularity::Row, "{}", spec.name);
            }
        }
    }
}
