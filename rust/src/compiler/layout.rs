//! Compile-time GEMM layout pass: pick each conv layer's slicing
//! granularity (row / pixel / channel-split) once, when the stream is
//! built, instead of per forward.
//!
//! The decision is a pure function of the layer command — kernel,
//! padded input width, lane-padded input channels — so it belongs on
//! the artifact next to the epoch schedule and the weight plan: the
//! serving hot path (`forward_compiled`, `forward_batch_compiled`)
//! reads [`crate::compiler::CompiledStream::granularities`] and never
//! re-derives it. The uncompiled classic flow still computes it on the
//! fly ([`crate::host::gemm::conv_granularity`] — the same function, so
//! both flows always agree).

use crate::host::gemm::{self, ConvGranularity};
use crate::net::graph::Network;
use crate::net::layer::OpType;

/// Granularity per engine layer (indexed like `net.engine_layers()`);
/// `None` for pool/idle layers, which have no GEMM layout to pick.
pub fn plan_granularities(net: &Network) -> Vec<Option<ConvGranularity>> {
    net.engine_layers()
        .iter()
        .map(|spec| {
            (spec.op == OpType::ConvRelu).then(|| {
                let icp = (spec.i_ch as usize).div_ceil(8) * 8;
                let pw = (spec.i_side + 2 * spec.padding) as usize;
                gemm::conv_granularity(spec.kernel as usize, pw, icp)
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::alexnet::alexnet;
    use crate::net::squeezenet::squeezenet_v11;

    #[test]
    fn alexnet_layers_span_all_three_granularities() {
        let net = alexnet();
        let layers = net.engine_layers();
        let plan = plan_granularities(&net);
        assert_eq!(plan.len(), layers.len());
        let by_name = |name: &str| {
            let i = layers.iter().position(|s| s.name == name).unwrap();
            plan[i]
        };
        // conv1 11×11: row slice 19976 > cache, pixel 968 fits.
        assert_eq!(by_name("conv1"), Some(ConvGranularity::Pixel));
        // conv3 3×3 over 256 ch at 13+2: row 3·15·256 = 11520 > cache.
        assert_eq!(by_name("conv3"), Some(ConvGranularity::Pixel));
        // fc6 6×6 over 256 ch: one window is 1152 words — channel split.
        assert_eq!(by_name("fc6"), Some(ConvGranularity::ChannelSplit));
        // fc7/fc8 1×1 over 512: row fits (1·1·512 = 512).
        assert_eq!(by_name("fc7"), Some(ConvGranularity::Row));
        // Pool layers have no conv layout.
        assert_eq!(by_name("pool1"), None);
    }

    #[test]
    fn squeezenet_is_all_row() {
        let net = squeezenet_v11();
        for (spec, g) in net.engine_layers().iter().zip(plan_granularities(&net)) {
            match g {
                Some(g) => assert_eq!(g, ConvGranularity::Row, "{}", spec.name),
                None => assert_ne!(spec.op, OpType::ConvRelu),
            }
        }
    }
}
