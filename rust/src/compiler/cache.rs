//! Small LRU cache with hit/miss accounting — shared by the per-worker
//! compiled-model cache and the coordinator's image-hash result cache.
//!
//! Capacities on the serving path are tiny (a handful of networks, a
//! few hundred result entries), so the store is a plain vector in
//! recency order: linear probes beat hash-map bookkeeping at this size
//! and keep the eviction order trivially auditable.

/// Fixed-capacity LRU: `insert` evicts the least-recently-used entry
/// when full, `get` refreshes recency.
#[derive(Clone, Debug)]
pub struct LruCache<K, V> {
    cap: usize,
    /// Entries in recency order — index 0 is the eviction candidate.
    entries: Vec<(K, V)>,
    hits: u64,
    misses: u64,
}

impl<K: Eq, V: Clone> LruCache<K, V> {
    /// `cap` must be at least 1.
    pub fn new(cap: usize) -> LruCache<K, V> {
        assert!(cap >= 1, "LRU capacity must be at least 1");
        LruCache { cap, entries: Vec::new(), hits: 0, misses: 0 }
    }

    /// Look up `key`, refreshing its recency on a hit.
    pub fn get(&mut self, key: &K) -> Option<V> {
        match self.entries.iter().position(|(k, _)| k == key) {
            Some(i) => {
                let entry = self.entries.remove(i);
                let value = entry.1.clone();
                self.entries.push(entry);
                self.hits += 1;
                Some(value)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) `key`, evicting the LRU entry when full.
    pub fn insert(&mut self, key: K, value: V) {
        if let Some(i) = self.entries.iter().position(|(k, _)| *k == key) {
            self.entries.remove(i);
        } else if self.entries.len() == self.cap {
            self.entries.remove(0);
        }
        self.entries.push((key, value));
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Lookups that found an entry.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookups that missed.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Hits over total lookups (0.0 before any lookup).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_recently_used() {
        let mut c: LruCache<u32, u32> = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        assert_eq!(c.get(&1), Some(10)); // 1 is now most recent
        c.insert(3, 30); // evicts 2
        assert_eq!(c.get(&2), None);
        assert_eq!(c.get(&1), Some(10));
        assert_eq!(c.get(&3), Some(30));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn reinsert_refreshes_instead_of_duplicating() {
        let mut c: LruCache<&str, u32> = LruCache::new(2);
        c.insert("a", 1);
        c.insert("b", 2);
        c.insert("a", 3); // refresh, not a new slot
        assert_eq!(c.len(), 2);
        c.insert("c", 4); // evicts "b" (LRU), not "a"
        assert_eq!(c.get(&"b"), None);
        assert_eq!(c.get(&"a"), Some(3));
    }

    #[test]
    fn hit_rate_accounting() {
        let mut c: LruCache<u32, u32> = LruCache::new(4);
        assert_eq!(c.hit_rate(), 0.0);
        c.insert(1, 1);
        assert!(c.get(&1).is_some());
        assert!(c.get(&2).is_none());
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }
}
