//! Static command-stream verifier: prove a [`CompiledStream`] safe
//! before it ever touches an engine.
//!
//! A malformed stream is a silent wrong answer (or a hang) on real
//! hardware — the CSB trusts its 12-byte commands, the caches trust the
//! compiler's bases, and RESFIFO trusts the drivers' drain placement.
//! This module walks an artifact command-by-command with an **abstract
//! machine model** of the engine state (cache occupancy intervals,
//! CMDFIFO epochs, RESFIFO high-water marks, the channel-split
//! partial-bias protocol) and either proves a fixed set of hardware
//! invariants or returns typed [`Violation`]s with stable error codes
//! and layer/command provenance. No engine execution, no weights, no
//! data — verification is pure arithmetic over the artifact.
//!
//! | code | invariant |
//! |------|-----------|
//! | `FA-SLICE-OVERFLOW`    | every data slice the recorded granularity implies fits the 1024-word data cache (incl. per-chunk split slices; giant *avg* pools are rejected — max-only fold) |
//! | `FA-WEIGHT-OVERFLOW`   | one output channel's weights fit the weight cache; resident plan intervals stay inside it |
//! | `FA-PLAN-OVERLAP`      | resident [`WeightPlan`] weight/bias intervals are pairwise disjoint |
//! | `FA-PLAN-RESERVED-BIAS`| no resident bias interval reaches the reserved top-8 partial-sum slots ([`PARTIAL_BIAS_BASE`]) |
//! | `FA-PLAN-GAP`          | a resident plan homes *every* conv super-block, and nothing else |
//! | `FA-EPOCH-OVERFLOW`    | every CMDFIFO epoch holds 1..=341 commands |
//! | `FA-TAPE-GAP`          | epochs tile the layer tape exactly (reloads only at epoch boundaries, every command covered once) |
//! | `FA-RESFIFO-OVERFLOW`  | no single engine pass produces more results than RESFIFO holds between drains |
//! | `FA-SPLIT-PROTOCOL`    | channel-split chunks run in channel order with drain barriers, real bias only on chunk 0, partial re-entry after, activation only on the last chunk |
//! | `FA-GRAN-ILLEGAL`      | every recorded granularity is a member of [`layout::legal_granularities`] for its layer |
//! | `FA-IDLE-CMD`          | no `Idle` command survives the pass pipeline (op 0 is the CSB end-of-stream sentinel) |
//! | `FA-DEAD-NODE`         | no dead node survives the pass pipeline |
//! | `FA-SLOT-ALIAS`        | parallel-branch slot tags fit their 4-bit field and match the concat convention after re-tagging |
//! | `FA-MODEL-DRIFT`       | [`CompiledStream::modeled`] equals a fresh re-run of [`cost::model_stream`] over the verified stream |
//! | `FA-SEAL-STALE`        | the stamped verification seal matches the artifact content ([`verify_sealed`]) |
//!
//! Checks are **staged** so a corrupt artifact yields violations, never
//! a panic: structural checks (epoch tiling, granularity legality,
//! per-channel weight fit) run first, and derived checks that replay
//! compiler arithmetic (plan intervals, split protocol, the cost-model
//! re-run) only run once their structural prerequisites hold.
//!
//! Wiring: [`super::compile`] rejects artifacts with Error-severity
//! findings and stamps [`CompiledStream::seal`] on clean ones;
//! [`super::registry::ModelRepo::serveable`] refuses to hand a worker
//! any artifact whose seal is missing or stale; `fusionaccel lint`
//! prints the report (nonzero exit on any Error). The mutation harness
//! (`rust/tests/verify_mutations.rs`) pins one deliberate corruption
//! per invariant class against its expected code, plus zero false
//! positives across the whole model zoo. Future artifact mutators —
//! the pipeline partitioner, the quantizer — must keep their outputs
//! clean under this verifier; it is the compilation contract.
//!
//! [`WeightPlan`]: crate::host::gemm::WeightPlan
//! [`PARTIAL_BIAS_BASE`]: crate::host::gemm::PARTIAL_BIAS_BASE

use std::fmt;

use crate::accel::stream::DATA_CACHE_WORDS;
use crate::engine::csb::MAX_LAYERS;
use crate::host::gemm::{
    self, ConvGranularity, DATA_CACHE_VALUES, PARTIAL_BIAS_BASE, RES_FIFO_VALUES,
    WEIGHT_CACHE_VALUES,
};
use crate::net::graph::{Network, Node};
use crate::net::layer::{LayerSpec, OpType};

use super::artifact::{graph_fingerprint, CompiledStream, Fingerprint};
use super::{cost, layout, passes};

pub const FA_SLICE_OVERFLOW: &str = "FA-SLICE-OVERFLOW";
pub const FA_WEIGHT_OVERFLOW: &str = "FA-WEIGHT-OVERFLOW";
pub const FA_PLAN_OVERLAP: &str = "FA-PLAN-OVERLAP";
pub const FA_PLAN_RESERVED_BIAS: &str = "FA-PLAN-RESERVED-BIAS";
pub const FA_PLAN_GAP: &str = "FA-PLAN-GAP";
pub const FA_EPOCH_OVERFLOW: &str = "FA-EPOCH-OVERFLOW";
pub const FA_TAPE_GAP: &str = "FA-TAPE-GAP";
pub const FA_RESFIFO_OVERFLOW: &str = "FA-RESFIFO-OVERFLOW";
pub const FA_SPLIT_PROTOCOL: &str = "FA-SPLIT-PROTOCOL";
pub const FA_GRAN_ILLEGAL: &str = "FA-GRAN-ILLEGAL";
pub const FA_IDLE_CMD: &str = "FA-IDLE-CMD";
pub const FA_DEAD_NODE: &str = "FA-DEAD-NODE";
pub const FA_SLOT_ALIAS: &str = "FA-SLOT-ALIAS";
pub const FA_MODEL_DRIFT: &str = "FA-MODEL-DRIFT";
pub const FA_SEAL_STALE: &str = "FA-SEAL-STALE";
/// Online conformance (serving-time): a batch's measured engine
/// counters diverged from the artifact's stamped cost model.
pub const FA_DRIFT_COST: &str = "FA-DRIFT-COST";
/// Online conformance (serving-time): a batch's observed RESFIFO
/// watermark exceeded the static verifier's worst-case occupancy bound.
pub const FA_DRIFT_OCCUPANCY: &str = "FA-DRIFT-OCCUPANCY";

/// How bad a finding is. `Error` findings make an artifact unservable;
/// `Warning`s are advisory (reported by `lint`, never gating).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Warning => write!(f, "warning"),
            Severity::Error => write!(f, "error"),
        }
    }
}

/// One typed finding: a stable error code, a severity, a human message,
/// and provenance (the engine layer's name and its command index on the
/// layer tape, when the finding is layer-scoped).
#[derive(Clone, Debug)]
pub struct Violation {
    pub code: &'static str,
    pub severity: Severity,
    pub message: String,
    /// Engine-layer name, for layer-scoped findings.
    pub layer: Option<String>,
    /// Command index in engine order (the layer-tape position).
    pub command: Option<usize>,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}]", self.severity, self.code)?;
        if let Some(layer) = &self.layer {
            write!(f, " layer {layer:?}")?;
        }
        if let Some(cmd) = self.command {
            write!(f, " (cmd {cmd})")?;
        }
        write!(f, ": {}", self.message)
    }
}

/// Everything the verifier found, in check order.
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    pub violations: Vec<Violation>,
}

impl VerifyReport {
    /// No findings at all (warnings included).
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Error-severity findings (the serve/compile gate).
    pub fn errors(&self) -> Vec<&Violation> {
        self.violations.iter().filter(|v| v.severity == Severity::Error).collect()
    }

    /// Whether any finding carries `code`.
    pub fn has_code(&self, code: &str) -> bool {
        self.violations.iter().any(|v| v.code == code)
    }

    /// Multi-line human rendering (one finding per line).
    pub fn render(&self) -> String {
        self.violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
    }
}

/// Where a channel-split chunk's bias-port load comes from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BiasSource {
    /// The layer's real bias block (chunk 0 only).
    Real,
    /// The previous chunk's drained partial sums, re-entered through
    /// [`PARTIAL_BIAS_BASE`].
    Partial,
}

/// One chunk of a channel-split layer's batched execution plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkStep {
    /// First input-channel group this chunk covers.
    pub group_start: usize,
    /// Input-channel groups in the chunk.
    pub group_count: usize,
    pub bias: BiasSource,
    /// Whether the fused ReLU applies to this chunk's results. Must be
    /// false on every chunk but the last (partials must not be clipped)
    /// and `!skip_relu` on the last.
    pub apply_activation: bool,
    /// Drain barrier after the chunk (the next chunk re-enters these
    /// partials; results must leave RESFIFO first).
    pub barrier: bool,
}

/// The explicit, verifier-checkable form of one channel-split layer's
/// partial-bias protocol. The drivers keep deriving the identical
/// schedule from [`gemm::channel_chunks`] at forward time; this record
/// exists so the protocol is *stated* on the artifact and statically
/// checkable, not implicit in driver loops.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SplitPlan {
    pub chunks: Vec<ChunkStep>,
}

/// Build the per-layer split plans for a compiled stream (indexed like
/// `net.engine_layers()`; `None` for layers that are not channel-split).
pub fn plan_splits(
    net: &Network,
    granularities: &[Option<ConvGranularity>],
) -> Vec<Option<SplitPlan>> {
    net.engine_layers()
        .iter()
        .enumerate()
        .map(|(eidx, spec)| {
            if granularities.get(eidx).copied().flatten() != Some(ConvGranularity::ChannelSplit) {
                return None;
            }
            let icp = (spec.i_ch as usize).div_ceil(8) * 8;
            let cc = gemm::channel_chunks(spec.kernel as usize, icp);
            let chunks = (0..cc.count)
                .map(|c| {
                    let (g0, gn) = cc.chunk(c);
                    ChunkStep {
                        group_start: g0,
                        group_count: gn,
                        bias: if c == 0 { BiasSource::Real } else { BiasSource::Partial },
                        apply_activation: c + 1 == cc.count && !spec.skip_relu,
                        barrier: true,
                    }
                })
                .collect();
            Some(SplitPlan { chunks })
        })
        .collect()
}

struct Checker {
    violations: Vec<Violation>,
}

impl Checker {
    fn push(
        &mut self,
        code: &'static str,
        severity: Severity,
        layer: Option<&str>,
        command: Option<usize>,
        message: String,
    ) {
        self.violations.push(Violation {
            code,
            severity,
            message,
            layer: layer.map(str::to_string),
            command,
        });
    }

    fn err(&mut self, code: &'static str, layer: &str, command: usize, message: String) {
        self.push(code, Severity::Error, Some(layer), Some(command), message);
    }

    fn err_global(&mut self, code: &'static str, message: String) {
        self.push(code, Severity::Error, None, None, message);
    }
}

/// Statically verify a compiled stream against every invariant in the
/// module table. Never panics — corrupt inputs come back as findings.
pub fn verify(cs: &CompiledStream) -> VerifyReport {
    let mut ck = Checker { violations: Vec::new() };
    let layers = cs.net.engine_layers();

    check_commands(&mut ck, &layers);
    check_dead_nodes(&mut ck, &cs.net);
    check_concat_slots(&mut ck, &cs.net);
    let grans_ok = check_granularities(&mut ck, cs, &layers);
    let weights_ok = check_weight_shapes(&mut ck, &layers);
    let epochs_ok = check_epochs(&mut ck, cs, layers.len());
    if grans_ok {
        check_slices(&mut ck, cs, &layers);
    }
    if weights_ok {
        check_weight_plan(&mut ck, cs, &layers);
    }
    if grans_ok && weights_ok {
        check_split_plans(&mut ck, cs, &layers);
        check_resfifo(&mut ck, cs, &layers);
    }
    // The model re-run replays compiler arithmetic (it indexes layers
    // through the epoch schedule and calls `conv_layout`), so it only
    // runs once the structural checks prove that arithmetic total.
    if grans_ok && weights_ok && epochs_ok {
        check_modeled(&mut ck, cs);
    }
    VerifyReport { violations: ck.violations }
}

/// [`verify`] plus the seal check: the stamped [`CompiledStream::seal`]
/// must equal a fresh [`artifact_seal`] of the artifact's content. A
/// mismatch means the artifact was mutated after compilation (or never
/// verified at all) — the serve-time gate
/// ([`super::registry::ModelRepo::serveable`]) keys off exactly this.
pub fn verify_sealed(cs: &CompiledStream) -> VerifyReport {
    let mut report = verify(cs);
    let want = artifact_seal(cs);
    if cs.seal != want {
        report.violations.insert(
            0,
            Violation {
                code: FA_SEAL_STALE,
                severity: Severity::Error,
                message: format!(
                    "stamped seal {:016x} does not match artifact content {want:016x} \
                     (mutated after compile, or never verified)",
                    cs.seal
                ),
                layer: None,
                command: None,
            },
        );
    }
    report
}

/// Content checksum over everything [`verify`] proves things about:
/// the optimized graph, the epoch schedule, the granularity record, the
/// weight plan, the split plans, and the stamped cost model. `compile`
/// stamps it onto [`CompiledStream::seal`] *after* a clean verification,
/// so `seal == artifact_seal(cs)` is the machine-checkable statement
/// "this exact content passed the verifier". The seal field itself is
/// excluded, of course.
pub fn artifact_seal(cs: &CompiledStream) -> u64 {
    let mut h = Fingerprint::new();
    h.bytes(b"fa-seal-v1")
        .str(&cs.id)
        .u64(cs.weights_id)
        .u64(cs.source_fingerprint)
        .u64(graph_fingerprint(&cs.net));
    h.u64(cs.epochs.len() as u64);
    for ep in &cs.epochs {
        h.u64(ep.start as u64).u64(ep.len as u64);
    }
    h.u64(cs.granularities.len() as u64);
    for g in &cs.granularities {
        h.u64(match g {
            None => 0,
            Some(ConvGranularity::Row) => 1,
            Some(ConvGranularity::Pixel) => 2,
            Some(ConvGranularity::ChannelSplit) => 3,
        });
    }
    let mut entries: Vec<_> = cs.weight_plan.entries().collect();
    entries.sort_by_key(|(key, _)| *key);
    h.u64(entries.len() as u64);
    for ((eidx, block), slot) in entries {
        h.u64(eidx as u64)
            .u64(block as u64)
            .u64(slot.weight_base as u64)
            .u64(slot.bias_base as u64)
            .str(&slot.key);
    }
    h.u64(cs.split_plans.len() as u64);
    for plan in &cs.split_plans {
        match plan {
            None => {
                h.u64(0);
            }
            Some(p) => {
                h.u64(1).u64(p.chunks.len() as u64);
                for c in &p.chunks {
                    h.u64(c.group_start as u64)
                        .u64(c.group_count as u64)
                        .u64(match c.bias {
                            BiasSource::Real => 0,
                            BiasSource::Partial => 1,
                        })
                        .u64(c.apply_activation as u64)
                        .u64(c.barrier as u64);
                }
            }
        }
    }
    seal_cost(&mut h, &cs.modeled);
    h.finish()
}

fn seal_cost(h: &mut Fingerprint, modeled: &cost::StreamCost) {
    h.u64(modeled.batch as u64)
        .u64(match modeled.residency {
            cost::Residency::Cold => 0,
            cost::Residency::Warm => 1,
        })
        .u64(modeled.command_loads)
        .u64(modeled.command_reuses);
    seal_layer_cost(h, &modeled.preamble);
    h.u64(modeled.layers.len() as u64);
    for l in &modeled.layers {
        seal_layer_cost(h, l);
    }
}

fn seal_layer_cost(h: &mut Fingerprint, l: &cost::LayerCost) {
    h.str(&l.name)
        .u64(l.passes)
        .u64(l.cycles)
        .u64(l.weight_loads)
        .u64(l.weight_reuses)
        .u64(l.link_bytes)
        .u64(l.link_txns);
}

/// Per-command structural checks: no Idle sentinel on the tape, slot
/// tag within its 4-bit command field.
fn check_commands(ck: &mut Checker, layers: &[&LayerSpec]) {
    for (cmd, spec) in layers.iter().enumerate() {
        if spec.op == OpType::Idle {
            ck.err(
                FA_IDLE_CMD,
                &spec.name,
                cmd,
                "Idle command on the tape: the CSB parses op 0 as end-of-stream and would \
                 desynchronize every later layer"
                    .to_string(),
            );
        }
        if spec.slot >= 16 {
            ck.err(
                FA_SLOT_ALIAS,
                &spec.name,
                cmd,
                format!("slot tag {} overflows the 4-bit command field", spec.slot),
            );
        }
    }
}

/// The pass pipeline must have converged: a dead node surviving on the
/// artifact would still cost commands, weights, and cycles.
fn check_dead_nodes(ck: &mut Checker, net: &Network) {
    let (_, removed) = passes::eliminate_dead(net);
    if removed > 0 {
        ck.err_global(
            FA_DEAD_NODE,
            format!("{removed} dead node(s) survived the pass pipeline"),
        );
    }
}

/// Parallel-branch slot tags must match the concat convention the
/// re-tagging pass ([`passes::retag_concat_slots`]) establishes —
/// checked under exactly the guard the pass uses, so a verified
/// artifact is also a fixpoint of the pass.
fn check_concat_slots(ck: &mut Checker, net: &Network) {
    let mut consumer_count = vec![0usize; net.nodes.len()];
    for node in &net.nodes {
        for j in node.inputs() {
            consumer_count[j] += 1;
        }
    }
    for node in &net.nodes {
        let Node::Concat { name, inputs } = node else { continue };
        if !(2..=4).contains(&inputs.len()) {
            continue;
        }
        let branches: Option<Vec<&LayerSpec>> = inputs
            .iter()
            .map(|&j| match &net.nodes[j] {
                Node::Engine { spec, .. } if consumer_count[j] == 1 => Some(spec),
                _ => None,
            })
            .collect();
        let Some(branches) = branches else { continue };
        let count = inputs.len() as u32 - 1;
        for (pos, spec) in branches.iter().enumerate() {
            let want = if inputs.len() == 2 {
                if pos == 0 {
                    1
                } else {
                    5
                }
            } else {
                (count << 2) | pos as u32
            };
            if spec.slot != want {
                ck.push(
                    FA_SLOT_ALIAS,
                    Severity::Error,
                    Some(&spec.name),
                    None,
                    format!(
                        "branch {pos} of {}-way concat {name:?} carries slot {} (convention: {want})",
                        inputs.len(),
                        spec.slot
                    ),
                );
            }
        }
    }
}

/// The granularity record must cover every engine layer, and every
/// recorded granularity must be legal for its layer's shape. Returns
/// whether the record is structurally usable by the derived checks.
fn check_granularities(ck: &mut Checker, cs: &CompiledStream, layers: &[&LayerSpec]) -> bool {
    if cs.granularities.len() != layers.len() {
        ck.err_global(
            FA_GRAN_ILLEGAL,
            format!(
                "granularity record covers {} layers but the tape has {}",
                cs.granularities.len(),
                layers.len()
            ),
        );
        return false;
    }
    let mut ok = true;
    for (cmd, spec) in layers.iter().enumerate() {
        let recorded = cs.granularities[cmd];
        match (spec.op, recorded) {
            (OpType::ConvRelu, Some(g)) => {
                if !layout::legal_granularities(spec).contains(&g) {
                    ck.err(
                        FA_GRAN_ILLEGAL,
                        &spec.name,
                        cmd,
                        format!("recorded granularity {g:?} is not legal for this layer shape"),
                    );
                }
            }
            (OpType::ConvRelu, None) => {
                ck.err(FA_GRAN_ILLEGAL, &spec.name, cmd, "conv layer has no recorded granularity".into());
                ok = false;
            }
            (_, Some(g)) => {
                ck.err(
                    FA_GRAN_ILLEGAL,
                    &spec.name,
                    cmd,
                    format!("non-conv layer carries granularity {g:?}"),
                );
            }
            (_, None) => {}
        }
    }
    ok
}

/// A single output channel's weights must fit the weight cache (the
/// super-block arithmetic divides by this; an overflow here would
/// panic every downstream consumer). Returns whether all convs pass.
fn check_weight_shapes(ck: &mut Checker, layers: &[&LayerSpec]) -> bool {
    let mut ok = true;
    for (cmd, spec) in layers.iter().enumerate() {
        if spec.op != OpType::ConvRelu {
            continue;
        }
        let icp = (spec.i_ch as usize).div_ceil(8) * 8;
        let per_oc = spec.kernel as usize * spec.kernel as usize * icp;
        if per_oc > WEIGHT_CACHE_VALUES {
            ck.err(
                FA_WEIGHT_OVERFLOW,
                &spec.name,
                cmd,
                format!(
                    "one output channel needs {per_oc} weight values > the \
                     {WEIGHT_CACHE_VALUES}-value weight cache"
                ),
            );
            ok = false;
        }
    }
    ok
}

/// Epochs must each fit the CMDFIFO and tile the tape exactly — command
/// reloads happen only at epoch boundaries, and every engine command is
/// covered exactly once. Returns whether the schedule is sound.
fn check_epochs(ck: &mut Checker, cs: &CompiledStream, n_layers: usize) -> bool {
    let mut ok = true;
    let mut cursor = 0usize;
    for (e, ep) in cs.epochs.iter().enumerate() {
        if ep.len == 0 || ep.len > MAX_LAYERS {
            ck.err_global(
                FA_EPOCH_OVERFLOW,
                format!(
                    "epoch {e} holds {} commands (CMDFIFO fits 1..={MAX_LAYERS})",
                    ep.len
                ),
            );
            ok = false;
        }
        if ep.start != cursor {
            ck.err_global(
                FA_TAPE_GAP,
                format!("epoch {e} starts at command {} but the tape cursor is {cursor}", ep.start),
            );
            ok = false;
        }
        cursor = ep.start + ep.len;
    }
    if cursor != n_layers {
        ck.err_global(
            FA_TAPE_GAP,
            format!("epochs cover {cursor} of {n_layers} commands"),
        );
        ok = false;
    }
    ok
}

/// Every data-cache slice the recorded granularity implies must fit the
/// 1024-word cache; giant avg pools (window > cache, no exact partial
/// fold) are rejected outright.
fn check_slices(ck: &mut Checker, cs: &CompiledStream, layers: &[&LayerSpec]) {
    for (cmd, spec) in layers.iter().enumerate() {
        let k = spec.kernel as usize;
        match spec.op {
            OpType::ConvRelu => {
                let icp = (spec.i_ch as usize).div_ceil(8) * 8;
                let pw = (spec.i_side + 2 * spec.padding) as usize;
                match cs.granularities[cmd] {
                    Some(ConvGranularity::Row) => {
                        let values = k * pw * icp;
                        if values > DATA_CACHE_VALUES {
                            ck.err(
                                FA_SLICE_OVERFLOW,
                                &spec.name,
                                cmd,
                                format!(
                                    "row slice is {values} values > the \
                                     {DATA_CACHE_VALUES}-value data cache"
                                ),
                            );
                        }
                    }
                    Some(ConvGranularity::Pixel) => {
                        let values = k * k * icp;
                        if values > DATA_CACHE_VALUES {
                            ck.err(
                                FA_SLICE_OVERFLOW,
                                &spec.name,
                                cmd,
                                format!(
                                    "pixel slice is {values} values > the \
                                     {DATA_CACHE_VALUES}-value data cache"
                                ),
                            );
                        }
                    }
                    Some(ConvGranularity::ChannelSplit) => {
                        let cc = gemm::channel_chunks(k, icp);
                        for c in 0..cc.count {
                            let words = cc.slice_words(c);
                            if words > DATA_CACHE_WORDS {
                                ck.err(
                                    FA_SLICE_OVERFLOW,
                                    &spec.name,
                                    cmd,
                                    format!(
                                        "split chunk {c} is {words} words > the \
                                         {DATA_CACHE_WORDS}-word data cache"
                                    ),
                                );
                            }
                        }
                    }
                    None => {} // already reported by check_granularities
                }
            }
            OpType::MaxPool | OpType::AvgPool => {
                if k * k > DATA_CACHE_WORDS && spec.op == OpType::AvgPool {
                    ck.err(
                        FA_SLICE_OVERFLOW,
                        &spec.name,
                        cmd,
                        format!(
                            "giant avg-pool window ({k}\u{d7}{k} > {DATA_CACHE_WORDS} words) has \
                             no exact partial fold (max-only; see pool_row_chunks)"
                        ),
                    );
                }
            }
            OpType::Idle => {}
        }
    }
}

/// A resident weight plan must home every conv super-block — and only
/// those — in pairwise-disjoint weight/bias intervals that stay inside
/// the caches and below the reserved partial-bias slots.
fn check_weight_plan(ck: &mut Checker, cs: &CompiledStream, layers: &[&LayerSpec]) {
    if !cs.weight_plan.is_resident() {
        return; // empty plan: every block loads at word 0, nothing to prove
    }
    // (eidx, block) -> resident output channels, from the layer shapes.
    let mut expected: Vec<((usize, usize), usize)> = Vec::new();
    for (eidx, spec) in layers.iter().enumerate() {
        if spec.op != OpType::ConvRelu {
            continue;
        }
        let l = gemm::conv_layout(spec.kernel as usize, spec.i_ch as usize, spec.o_ch as usize);
        let o_ch = spec.o_ch as usize;
        let mut oc0 = 0usize;
        let mut block = 0usize;
        while oc0 < o_ch {
            let resident = l.super_block.min(o_ch - oc0);
            expected.push(((eidx, block), resident));
            oc0 += resident;
            block += 1;
        }
    }

    let mut weight_iv: Vec<(usize, usize, usize)> = Vec::new(); // (start, end, eidx)
    let mut bias_iv: Vec<(usize, usize, usize)> = Vec::new();
    for &((eidx, block), resident) in &expected {
        let spec = layers[eidx];
        let Some(slot) = cs.weight_plan.slot(eidx, block) else {
            ck.err(
                FA_PLAN_GAP,
                &spec.name,
                eidx,
                format!("resident plan has no home for super-block {block}"),
            );
            continue;
        };
        let l = gemm::conv_layout(spec.kernel as usize, spec.i_ch as usize, spec.o_ch as usize);
        let wlen = resident * l.per_oc_values / 8;
        let wend = slot.weight_base + wlen;
        if wend > WEIGHT_CACHE_VALUES / 8 {
            ck.err(
                FA_WEIGHT_OVERFLOW,
                &spec.name,
                eidx,
                format!(
                    "super-block {block} home [{}, {wend}) overflows the {}-word weight cache",
                    slot.weight_base,
                    WEIGHT_CACHE_VALUES / 8
                ),
            );
        }
        let bend = slot.bias_base + resident;
        if bend > PARTIAL_BIAS_BASE {
            ck.err(
                FA_PLAN_RESERVED_BIAS,
                &spec.name,
                eidx,
                format!(
                    "super-block {block} biases [{}, {bend}) reach the reserved partial-sum \
                     slots at {PARTIAL_BIAS_BASE} (every chunked pass would evict a resident)",
                    slot.bias_base
                ),
            );
        }
        weight_iv.push((slot.weight_base, wend, eidx));
        bias_iv.push((slot.bias_base, bend, eidx));
    }

    // Anything planned beyond the expected block set is a forged home.
    let expected_keys: std::collections::HashSet<(usize, usize)> =
        expected.iter().map(|(k, _)| *k).collect();
    for (key, _) in cs.weight_plan.entries() {
        if !expected_keys.contains(&key) {
            ck.err_global(
                FA_PLAN_GAP,
                format!("plan homes nonexistent super-block (layer {}, block {})", key.0, key.1),
            );
        }
    }

    for (kind, iv, code) in
        [("weight", &mut weight_iv, FA_PLAN_OVERLAP), ("bias", &mut bias_iv, FA_PLAN_OVERLAP)]
    {
        iv.sort_unstable();
        for pair in iv.windows(2) {
            let (_, a_end, a_eidx) = pair[0];
            let (b_start, _, b_eidx) = pair[1];
            if b_start < a_end {
                ck.err(
                    code,
                    &layers[b_eidx].name,
                    b_eidx,
                    format!(
                        "{kind} interval overlaps layer {:?}'s (a later load would evict a \
                         block the plan promises is resident)",
                        layers[a_eidx].name
                    ),
                );
            }
        }
    }
}

/// The channel-split partial-bias protocol, checked against the layer's
/// canonical chunking: real bias only on chunk 0, partial re-entry
/// after, chunks in ascending channel order tiling every group, the
/// activation only on the last chunk, and a drain barrier everywhere.
fn check_split_plans(ck: &mut Checker, cs: &CompiledStream, layers: &[&LayerSpec]) {
    if cs.split_plans.len() != layers.len() {
        ck.err_global(
            FA_SPLIT_PROTOCOL,
            format!(
                "split-plan record covers {} layers but the tape has {}",
                cs.split_plans.len(),
                layers.len()
            ),
        );
        return;
    }
    for (cmd, spec) in layers.iter().enumerate() {
        let is_split = cs.granularities[cmd] == Some(ConvGranularity::ChannelSplit);
        let plan = &cs.split_plans[cmd];
        match (is_split, plan) {
            (false, None) => continue,
            (false, Some(_)) => {
                ck.err(
                    FA_SPLIT_PROTOCOL,
                    &spec.name,
                    cmd,
                    "non-split layer carries a split plan".into(),
                );
                continue;
            }
            (true, None) => {
                ck.err(
                    FA_SPLIT_PROTOCOL,
                    &spec.name,
                    cmd,
                    "channel-split layer has no split plan".into(),
                );
                continue;
            }
            (true, Some(_)) => {}
        }
        let plan = plan.as_ref().unwrap();
        let icp = (spec.i_ch as usize).div_ceil(8) * 8;
        let cc = gemm::channel_chunks(spec.kernel as usize, icp);
        if plan.chunks.len() != cc.count {
            ck.err(
                FA_SPLIT_PROTOCOL,
                &spec.name,
                cmd,
                format!("{} chunks planned, canonical chunking has {}", plan.chunks.len(), cc.count),
            );
            continue;
        }
        let last = plan.chunks.len() - 1;
        let mut cursor = 0usize;
        for (c, step) in plan.chunks.iter().enumerate() {
            if step.group_start != cursor {
                ck.err(
                    FA_SPLIT_PROTOCOL,
                    &spec.name,
                    cmd,
                    format!(
                        "chunk {c} starts at group {} but the channel cursor is {cursor} \
                         (chunks must run in ascending channel order, tiling every group)",
                        step.group_start
                    ),
                );
            }
            cursor = step.group_start + step.group_count;
            let want_bias = if c == 0 { BiasSource::Real } else { BiasSource::Partial };
            if step.bias != want_bias {
                ck.err(
                    FA_SPLIT_PROTOCOL,
                    &spec.name,
                    cmd,
                    format!(
                        "chunk {c} bias source is {:?} (the real bias loads only on chunk 0; \
                         later chunks re-enter the previous partial)",
                        step.bias
                    ),
                );
            }
            let want_act = c == last && !spec.skip_relu;
            if step.apply_activation != want_act {
                ck.err(
                    FA_SPLIT_PROTOCOL,
                    &spec.name,
                    cmd,
                    format!(
                        "chunk {c} activation is {} (an activation mid-split would clip \
                         partial sums; it applies exactly once, on the last chunk)",
                        step.apply_activation
                    ),
                );
            }
            if !step.barrier {
                ck.err(
                    FA_SPLIT_PROTOCOL,
                    &spec.name,
                    cmd,
                    format!("chunk {c} has no drain barrier (the next chunk re-enters its partials)"),
                );
            }
            let words = spec.kernel as usize * spec.kernel as usize * step.group_count;
            if words > DATA_CACHE_WORDS {
                ck.err(
                    FA_SLICE_OVERFLOW,
                    &spec.name,
                    cmd,
                    format!("chunk {c} slice is {words} words > the {DATA_CACHE_WORDS}-word data cache"),
                );
            }
        }
        if cursor != cc.groups {
            ck.err(
                FA_SPLIT_PROTOCOL,
                &spec.name,
                cmd,
                format!("chunks cover {cursor} of {} channel groups", cc.groups),
            );
        }
    }
}

/// Worst-case single-pass RESFIFO occupancy for one engine layer — the
/// most results a single `restart_engine` pulse can push before the
/// host gets a chance to drain. `None` for layers the engine never
/// produces into the FIFO for (Idle) or convs with no planned
/// granularity. This is the quantity [`check_resfifo`] gates statically
/// and the online conformance checker compares device watermarks
/// against at serving time.
pub fn resfifo_worst_case(spec: &LayerSpec, gran: Option<ConvGranularity>) -> Option<usize> {
    let k = spec.kernel as usize;
    let o = spec.o_side as usize;
    match spec.op {
        OpType::ConvRelu => {
            let l = gemm::conv_layout(k, spec.i_ch as usize, spec.o_ch as usize);
            match gran {
                // Row passes push one whole output row per oc step.
                Some(ConvGranularity::Row) => Some(o * l.oc_pass),
                // Pixel/split passes push one result per oc.
                Some(ConvGranularity::Pixel) | Some(ConvGranularity::ChannelSplit) => {
                    Some(l.oc_pass)
                }
                None => None,
            }
        }
        OpType::MaxPool | OpType::AvgPool => {
            if k * k > DATA_CACHE_WORDS {
                Some(8) // giant windows: one 8-lane result per pass
            } else {
                Some(
                    gemm::pool_col_chunks(
                        k,
                        spec.stride as usize,
                        spec.padding as usize,
                        spec.i_side as usize,
                        o,
                    )
                    .iter()
                    .map(|c| c.cols * 8)
                    .max()
                    .unwrap_or(0),
                )
            }
        }
        OpType::Idle => None,
    }
}

/// The stream-wide worst-case occupancy: the max of the per-layer
/// [`resfifo_worst_case`] bounds. A driver that drains after every pass
/// (the single-image path) can never observe a RESFIFO watermark above
/// this; the batched driver coalesces drains, so its watermark is
/// additionally bounded by the FIFO capacity itself.
pub fn resfifo_stream_bound(cs: &CompiledStream) -> u64 {
    cs.net
        .engine_layers()
        .iter()
        .enumerate()
        .filter_map(|(cmd, spec)| resfifo_worst_case(spec, cs.granularities[cmd]))
        .max()
        .unwrap_or(0) as u64
}

/// No single engine pass may produce more results than RESFIFO holds:
/// both drivers drain *between* passes (the batched path checks `space`
/// before each pass), so the static safety condition is exactly that
/// every per-pass result group fits the 1024-value FIFO.
fn check_resfifo(ck: &mut Checker, cs: &CompiledStream, layers: &[&LayerSpec]) {
    for (cmd, spec) in layers.iter().enumerate() {
        let worst = match resfifo_worst_case(spec, cs.granularities[cmd]) {
            Some(w) => w,
            None => continue,
        };
        if worst > RES_FIFO_VALUES {
            ck.err(
                FA_RESFIFO_OVERFLOW,
                &spec.name,
                cmd,
                format!(
                    "one pass produces {worst} results > the {RES_FIFO_VALUES}-value RESFIFO \
                     (no drain can be placed inside a pass)"
                ),
            );
        }
    }
}

/// The stamped cost model must equal a fresh re-run over the verified
/// stream — a drifted `modeled` would misprice cold-start deadlines and
/// lie to `explain`.
fn check_modeled(ck: &mut Checker, cs: &CompiledStream) {
    if cs.modeled.batch == 0 {
        ck.err_global(FA_MODEL_DRIFT, "stamped model claims batch 0".into());
        return;
    }
    let fresh = cost::model_stream(
        &cs.net,
        &cs.epochs,
        cs.weight_plan.is_resident(),
        &cs.granularities,
        cs.modeled.batch,
        cs.modeled.residency,
    );
    if fresh != cs.modeled {
        ck.err_global(
            FA_MODEL_DRIFT,
            format!(
                "stamped cost model drifts from a re-run (stamped total cycles {}, fresh {})",
                cs.modeled.total().cycles,
                fresh.total().cycles
            ),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::net::squeezenet::micro_squeezenet;

    #[test]
    fn compiled_micro_net_verifies_clean_and_sealed() {
        let cs = compile(&micro_squeezenet(), 1).unwrap();
        let report = verify_sealed(&cs);
        assert!(report.is_clean(), "{}", report.render());
        assert_eq!(cs.seal, artifact_seal(&cs));
    }

    #[test]
    fn seal_tracks_content() {
        let cs = compile(&micro_squeezenet(), 1).unwrap();
        let mut bent = cs.clone();
        bent.epochs[0].len += 1;
        assert_ne!(artifact_seal(&bent), cs.seal);
        let report = verify_sealed(&bent);
        assert!(report.has_code(FA_SEAL_STALE), "{}", report.render());
    }

    #[test]
    fn violations_render_with_provenance() {
        let v = Violation {
            code: FA_EPOCH_OVERFLOW,
            severity: Severity::Error,
            message: "boom".into(),
            layer: Some("conv1".into()),
            command: Some(3),
        };
        let s = v.to_string();
        assert!(s.contains("error[FA-EPOCH-OVERFLOW]"), "{s}");
        assert!(s.contains("conv1") && s.contains("cmd 3"), "{s}");
    }

    #[test]
    fn split_plans_follow_the_protocol_by_construction() {
        let net = crate::net::alexnet::fc6_tail(16, 10);
        let cs = compile(&net, 1).unwrap();
        let idx = cs
            .granularities
            .iter()
            .position(|g| *g == Some(ConvGranularity::ChannelSplit))
            .expect("fc6 tail must contain a channel-split layer");
        let plan = cs.split_plans[idx].as_ref().unwrap();
        assert!(plan.chunks.len() >= 2);
        assert_eq!(plan.chunks[0].bias, BiasSource::Real);
        assert!(plan.chunks[1..].iter().all(|c| c.bias == BiasSource::Partial));
        assert!(plan.chunks.iter().all(|c| c.barrier));
        let last = plan.chunks.len() - 1;
        assert!(plan.chunks[..last].iter().all(|c| !c.apply_activation));
    }
}
