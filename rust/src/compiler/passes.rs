//! Graph-rewriting passes: lower a general inference DAG onto what the
//! CSD actually executes.
//!
//! Every pass is **bit-preserving on the network output** — the FP16
//! values of the final node are unchanged (property-tested in
//! `tests/compiler_pipeline.rs`); passes may drop or rewrite interior
//! nodes freely. The pipeline runs to a fixpoint, so chained rewrites
//! (e.g. `relu(relu(conv))`) converge without special-casing.
//!
//! | pass             | rewrite                                            |
//! |------------------|----------------------------------------------------|
//! | `fuse_conv_relu` | standalone ReLU into its producing conv's fused    |
//! |                  | activation (§3.2: ReLU is a sign-bit test in the   |
//! |                  | conv datapath), or dropped if the conv already     |
//! |                  | applies it                                         |
//! | `fold_pool_relu` | ReLU adjacent to max-pooling dropped: the RTL      |
//! |                  | comparator initializes at 0x0000 (Fig 26), so the  |
//! |                  | pool command absorbs the activation on both sides  |
//! | `fold_avgpool_head` | trailing ReLU of a global-average head dropped: |
//! |                  | when the avg-pool's producer is a conv with its    |
//! |                  | fused activation, every pooled value is already    |
//! |                  | non-negative and the ReLU is an identity           |
//! | `strip_idle`     | `Idle` engine nodes removed (they would desync the |
//! |                  | CSB, which treats op 0 as end-of-stream)           |
//! | `eliminate_dead` | nodes unreachable from the output removed, so dead |
//! |                  | branches never cost commands, weights, or cycles   |
//! | `retag_concat_slots` | parallel branches feeding a concat get the     |
//! |                  | §4.4 slot convention re-stamped (2-way: 1/5;       |
//! |                  | n-way: `(n-1)<<2 \| pos`), so front-ends that      |
//! |                  | leave slots at 0 still produce correctly tagged    |
//! |                  | commands; the verifier checks the same convention  |
//! |                  | (`FA-SLOT-ALIAS`), so aliasing is caught statically|
//!
//! Adding a pass: write `fn my_pass(&Network) -> (Network, usize)`
//! returning the rewritten graph and a change count (0 = unchanged;
//! the [`rebuild`] helper handles node dropping + edge rewiring), then
//! append it to [`PIPELINE`]. Rules: never reorder surviving engine
//! nodes (the CSB consumes commands in graph order), and keep the
//! output bits identical — extend the property test if in doubt.

use crate::net::graph::{Network, Node};
use crate::net::layer::OpType;

/// What one pass did across all fixpoint rounds.
#[derive(Clone, Debug)]
pub struct PassOutcome {
    pub name: &'static str,
    /// Nodes fused, folded, or removed by this pass.
    pub changed: usize,
}

/// Per-pass change counts for one compilation.
#[derive(Clone, Debug, Default)]
pub struct PassReport {
    pub passes: Vec<PassOutcome>,
}

impl PassReport {
    /// Total graph rewrites across all passes.
    pub fn total_changes(&self) -> usize {
        self.passes.iter().map(|p| p.changed).sum()
    }

    /// Compact `pass×count` rendering, e.g. `"fuse_conv_relu×2"`.
    pub fn summary(&self) -> String {
        let parts: Vec<String> = self
            .passes
            .iter()
            .filter(|p| p.changed > 0)
            .map(|p| format!("{}×{}", p.name, p.changed))
            .collect();
        if parts.is_empty() {
            "no-op".to_string()
        } else {
            parts.join(" ")
        }
    }
}

type PassFn = fn(&Network) -> (Network, usize);

/// The default pipeline, in order. See the module docs for the per-pass
/// contracts and how to extend it.
pub const PIPELINE: [(&str, PassFn); 6] = [
    ("fuse_conv_relu", fuse_conv_relu),
    ("fold_pool_relu", fold_pool_relu),
    ("fold_avgpool_head", fold_avgpool_head),
    ("strip_idle", strip_idle),
    ("eliminate_dead", eliminate_dead),
    ("retag_concat_slots", retag_concat_slots),
];

/// Run [`PIPELINE`] to a fixpoint (bounded — each round that changes
/// anything strictly shrinks or simplifies the graph).
pub fn run_pipeline(net: &Network) -> (Network, PassReport) {
    let mut report = PassReport {
        passes: PIPELINE.iter().map(|(name, _)| PassOutcome { name, changed: 0 }).collect(),
    };
    let mut cur = net.clone();
    // Every change removes a node or clears a flag, so rounds are
    // bounded by the node count; the cap is belt and braces.
    for _ in 0..=net.nodes.len() {
        let mut round_changes = 0;
        for (i, (_, pass)) in PIPELINE.iter().enumerate() {
            let (next, changed) = pass(&cur);
            report.passes[i].changed += changed;
            round_changes += changed;
            cur = next;
        }
        if round_changes == 0 {
            break;
        }
    }
    (cur, report)
}

/// Consumer lists: `consumers[i]` = nodes that read node `i`.
fn consumers(net: &Network) -> Vec<Vec<usize>> {
    let mut cons = vec![Vec::new(); net.nodes.len()];
    for (i, node) in net.nodes.iter().enumerate() {
        for j in node.inputs() {
            cons[j].push(i);
        }
    }
    cons
}

/// Rebuild a network dropping the marked nodes. Edges into a dropped
/// node are redirected to `repl[node]` (transitively). Dropped nodes
/// that are still referenced must have `repl[i] != i`; dead nodes
/// (unreferenced) may keep the default.
fn rebuild(net: &Network, drop: &[bool], repl: &[usize]) -> Network {
    let n = net.nodes.len();
    let resolve = |mut i: usize| {
        let mut steps = 0;
        while drop[i] {
            assert!(repl[i] != i, "dropped node {i} is still referenced");
            i = repl[i];
            steps += 1;
            assert!(steps <= n, "replacement cycle at node {i}");
        }
        i
    };
    let mut new_index = vec![usize::MAX; n];
    let mut out = Network::new(&net.name);
    for i in 0..n {
        if drop[i] {
            continue;
        }
        // Replacements always point backwards, so resolved targets are
        // already renumbered when we get here.
        let node = match &net.nodes[i] {
            Node::Input { side, ch } => Node::Input { side: *side, ch: *ch },
            Node::Engine { spec, input } => {
                Node::Engine { spec: spec.clone(), input: new_index[resolve(*input)] }
            }
            Node::Concat { name, inputs } => Node::Concat {
                name: name.clone(),
                inputs: inputs.iter().map(|&j| new_index[resolve(j)]).collect(),
            },
            Node::Softmax { name, input } => {
                Node::Softmax { name: name.clone(), input: new_index[resolve(*input)] }
            }
            Node::Relu { name, input } => {
                Node::Relu { name: name.clone(), input: new_index[resolve(*input)] }
            }
        };
        out.nodes.push(node);
        new_index[i] = out.nodes.len() - 1;
    }
    out
}

/// Fuse standalone [`Node::Relu`] nodes into their producing
/// convolution (clearing `skip_relu`) when *every* consumer of the conv
/// is a ReLU — otherwise another branch still needs the pre-activation
/// values. A ReLU after a conv that already applies its fused ReLU is
/// plain redundant and dropped.
pub fn fuse_conv_relu(net: &Network) -> (Network, usize) {
    let cons = consumers(net);
    let n = net.nodes.len();
    let mut out = net.clone();
    let mut drop = vec![false; n];
    let mut repl: Vec<usize> = (0..n).collect();
    let mut changed = 0;
    for i in 0..n {
        let Node::Relu { input, .. } = &net.nodes[i] else { continue };
        let src = *input;
        let Node::Engine { spec, .. } = &net.nodes[src] else { continue };
        if spec.op != OpType::ConvRelu {
            continue;
        }
        let fusable = !spec.skip_relu
            || cons[src].iter().all(|&c| matches!(net.nodes[c], Node::Relu { .. }));
        if !fusable {
            continue;
        }
        if spec.skip_relu {
            if let Node::Engine { spec, .. } = &mut out.nodes[src] {
                spec.skip_relu = false;
            }
        }
        drop[i] = true;
        repl[i] = src;
        changed += 1;
    }
    if changed == 0 {
        return (out, 0);
    }
    (rebuild(&out, &drop, &repl), changed)
}

/// Drop ReLU nodes that max-pooling absorbs. The RTL max comparator
/// initializes at 0x0000 (Fig 26), so a maxpool command computes
/// `max(0, window)` — which equals `relu(maxpool(x))` *and*
/// `maxpool(relu(x))`. A ReLU directly after a maxpool, or one consumed
/// exclusively by maxpools, is therefore free.
pub fn fold_pool_relu(net: &Network) -> (Network, usize) {
    let cons = consumers(net);
    let n = net.nodes.len();
    let mut drop = vec![false; n];
    let mut repl: Vec<usize> = (0..n).collect();
    let mut changed = 0;
    let is_maxpool = |i: usize| {
        matches!(&net.nodes[i], Node::Engine { spec, .. } if spec.op == OpType::MaxPool)
    };
    for i in 0..n {
        let Node::Relu { input, .. } = &net.nodes[i] else { continue };
        let after_pool = is_maxpool(*input);
        let before_pools = !cons[i].is_empty() && cons[i].iter().all(|&c| is_maxpool(c));
        if after_pool || before_pools {
            drop[i] = true;
            repl[i] = *input;
            changed += 1;
        }
    }
    if changed == 0 {
        return (net.clone(), 0);
    }
    (rebuild(net, &drop, &repl), changed)
}

/// Drop the trailing ReLU of a global-average-pool head — the
/// conv+avgpool adjacency of the ROADMAP "folding for global-average
/// heads" item. Average pooling can never absorb a *preceding* ReLU
/// (the mean of negatives is not 0 — `fold_pool_relu` deliberately
/// leaves it alone), but when the avg-pool's producer is a convolution
/// with its fused activation applied (`!skip_relu`), every window it
/// averages is non-negative, so the pooled values are non-negative too
/// (FP16 sums and divisions of non-negatives keep the sign bit clear)
/// and a ReLU consuming the pool is bitwise an identity. The pass
/// re-tags that adjacency by dropping the ReLU node; the conservative
/// conv-producer condition is what makes the rewrite provable from the
/// commands alone.
pub fn fold_avgpool_head(net: &Network) -> (Network, usize) {
    let n = net.nodes.len();
    let mut drop = vec![false; n];
    let mut repl: Vec<usize> = (0..n).collect();
    let mut changed = 0;
    for i in 0..n {
        let Node::Relu { input, .. } = &net.nodes[i] else { continue };
        let pool = *input;
        let Node::Engine { spec, input: pool_in } = &net.nodes[pool] else { continue };
        if spec.op != OpType::AvgPool {
            continue;
        }
        let Node::Engine { spec: producer, .. } = &net.nodes[*pool_in] else { continue };
        if producer.op != OpType::ConvRelu || producer.skip_relu {
            continue; // pre-activation values can be negative: keep it
        }
        drop[i] = true;
        repl[i] = pool;
        changed += 1;
    }
    if changed == 0 {
        return (net.clone(), 0);
    }
    (rebuild(net, &drop, &repl), changed)
}

/// Remove `Idle` engine nodes. They are identities to the functional
/// semantics but poison the command stream: the CSB parses op 0 as
/// end-of-stream ([`crate::engine::csb::Csb::next_layer`]), so a loaded
/// Idle command desynchronizes every layer after it.
pub fn strip_idle(net: &Network) -> (Network, usize) {
    let n = net.nodes.len();
    let mut drop = vec![false; n];
    let mut repl: Vec<usize> = (0..n).collect();
    let mut changed = 0;
    for i in 0..n {
        if let Node::Engine { spec, input } = &net.nodes[i] {
            if spec.op == OpType::Idle {
                drop[i] = true;
                repl[i] = *input;
                changed += 1;
            }
        }
    }
    if changed == 0 {
        return (net.clone(), 0);
    }
    (rebuild(net, &drop, &repl), changed)
}

/// Remove nodes that cannot reach the output (the last node). Dead
/// engine branches would otherwise still be loaded as commands, still
/// transfer weights, and still burn engine passes. Input nodes are
/// always kept — the driver validates the request image against them.
pub fn eliminate_dead(net: &Network) -> (Network, usize) {
    let n = net.nodes.len();
    if n == 0 {
        return (net.clone(), 0);
    }
    let mut live = vec![false; n];
    let mut stack = vec![n - 1];
    while let Some(i) = stack.pop() {
        if live[i] {
            continue;
        }
        live[i] = true;
        stack.extend(net.nodes[i].inputs());
    }
    for (i, node) in net.nodes.iter().enumerate() {
        if matches!(node, Node::Input { .. }) {
            live[i] = true;
        }
    }
    let drop: Vec<bool> = live.iter().map(|&l| !l).collect();
    let changed = drop.iter().filter(|&&d| d).count();
    if changed == 0 {
        return (net.clone(), 0);
    }
    let repl: Vec<usize> = (0..n).collect(); // dead nodes are unreferenced
    (rebuild(net, &drop, &repl), changed)
}

/// Re-stamp the §4.4 parallel-layer slot convention onto branches
/// feeding a concat: 2-way concats tag their branches 1/5 (the fire
/// module pair), n-way concats `(n-1) << 2 | position`. Slot tags are
/// command metadata (the datapath never reads them), so the rewrite is
/// trivially bit-preserving — but a front-end that leaves every slot at
/// 0 would emit aliased commands, and the static verifier pins the same
/// convention (`FA-SLOT-ALIAS`), so this pass is what makes builder
/// graphs verify. Guarded to concats of 2..=4 all-engine branches whose
/// *sole* consumer is that concat (a shared branch belongs to no single
/// concat, and rewriting it would toggle forever).
pub fn retag_concat_slots(net: &Network) -> (Network, usize) {
    let cons = consumers(net);
    let mut out = net.clone();
    let mut changed = 0;
    for node in &net.nodes {
        let Node::Concat { inputs, .. } = node else { continue };
        if !(2..=4).contains(&inputs.len()) {
            continue;
        }
        let sole_engine_branches = inputs
            .iter()
            .all(|&j| matches!(net.nodes[j], Node::Engine { .. }) && cons[j].len() == 1);
        if !sole_engine_branches {
            continue;
        }
        let count = inputs.len() as u32 - 1;
        for (pos, &j) in inputs.iter().enumerate() {
            let want = if inputs.len() == 2 {
                if pos == 0 {
                    1
                } else {
                    5
                }
            } else {
                (count << 2) | pos as u32
            };
            if let Node::Engine { spec, .. } = &mut out.nodes[j] {
                if spec.slot != want {
                    spec.slot = want;
                    changed += 1;
                }
            }
        }
    }
    (out, changed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::layer::LayerSpec;

    fn conv_no_act(name: &str, side: u32, ic: u32, oc: u32) -> LayerSpec {
        let mut s = LayerSpec::conv(name, 3, 1, 1, side, ic, oc, 0);
        s.skip_relu = true;
        s
    }

    fn engine_spec<'a>(net: &'a Network, name: &str) -> &'a LayerSpec {
        match &net.nodes[net.find(name).unwrap()] {
            Node::Engine { spec, .. } => spec,
            other => panic!("{name} is not an engine node: {other:?}"),
        }
    }

    #[test]
    fn relu_fuses_into_sole_consumer_conv() {
        let mut n = Network::new("t");
        let inp = n.input(8, 3);
        let c1 = n.engine(conv_no_act("c1", 8, 3, 4), inp);
        let r = n.relu("r", c1);
        n.softmax("prob", r);
        let (opt, report) = run_pipeline(&n);
        opt.check().unwrap();
        assert_eq!(report.total_changes(), 1);
        assert!(opt.find("r").is_none(), "relu node must be gone");
        assert!(!engine_spec(&opt, "c1").skip_relu, "activation fused into the command");
        assert_eq!(opt.nodes.len(), 3);
    }

    #[test]
    fn relu_not_fused_when_preactivation_is_shared() {
        // c1 feeds both a relu and a second conv directly: the second
        // branch needs pre-activation values, so the relu must survive
        // as a host node.
        let mut n = Network::new("t");
        let inp = n.input(8, 3);
        let c1 = n.engine(conv_no_act("c1", 8, 3, 4), inp);
        let r = n.relu("r", c1);
        let a = n.engine(LayerSpec::conv("a", 1, 1, 0, 8, 4, 4, 0), r);
        let b = n.engine(LayerSpec::conv("b", 1, 1, 0, 8, 4, 4, 0), c1);
        let cat = n.concat("cat", vec![a, b]);
        n.softmax("prob", cat);
        let (opt, _) = run_pipeline(&n);
        opt.check().unwrap();
        assert!(opt.find("r").is_some(), "shared pre-activation: relu must remain");
        assert!(engine_spec(&opt, "c1").skip_relu);
    }

    #[test]
    fn chained_relus_converge_to_one_fusion() {
        let mut n = Network::new("t");
        let inp = n.input(8, 3);
        let c1 = n.engine(conv_no_act("c1", 8, 3, 4), inp);
        let r1 = n.relu("r1", c1);
        let r2 = n.relu("r2", r1);
        n.softmax("prob", r2);
        let (opt, _) = run_pipeline(&n);
        opt.check().unwrap();
        assert!(opt.find("r1").is_none() && opt.find("r2").is_none());
        assert!(!engine_spec(&opt, "c1").skip_relu);
        assert_eq!(opt.nodes.len(), 3);
    }

    #[test]
    fn pool_absorbs_relu_on_both_sides() {
        let mut n = Network::new("t");
        let inp = n.input(8, 4);
        let r_in = n.relu("r_in", inp); // relu before a maxpool
        let p = n.engine(LayerSpec::maxpool("p", 2, 2, 8, 4), r_in);
        let r_out = n.relu("r_out", p); // relu after a maxpool
        n.softmax("prob", r_out);
        let (opt, report) = run_pipeline(&n);
        opt.check().unwrap();
        assert!(opt.find("r_in").is_none());
        assert!(opt.find("r_out").is_none());
        assert_eq!(report.total_changes(), 2);
        // avg pooling must NOT absorb a relu (mean of negatives ≠ 0).
        let mut m = Network::new("avg");
        let inp = m.input(8, 4);
        let r = m.relu("r", inp);
        let a = m.engine(LayerSpec::avgpool("a", 2, 2, 8, 4), r);
        m.softmax("prob", a);
        let (opt, _) = run_pipeline(&m);
        assert!(opt.find("r").is_some());
    }

    #[test]
    fn avgpool_head_drops_trailing_relu_after_activated_conv() {
        // conv (fused relu) → global avg → relu → softmax: the trailing
        // relu consumes provably non-negative values and folds away.
        let mut n = Network::new("gap_head");
        let inp = n.input(8, 3);
        let c1 = n.engine(LayerSpec::conv("c1", 3, 1, 1, 8, 3, 4, 0), inp);
        let gap = n.engine(LayerSpec::avgpool("gap", 8, 1, 8, 4), c1);
        let r = n.relu("r", gap);
        n.softmax("prob", r);
        let (opt, report) = run_pipeline(&n);
        opt.check().unwrap();
        assert!(opt.find("r").is_none(), "trailing relu must fold into the gap head");
        assert!(report.summary().contains("fold_avgpool_head×1"), "{}", report.summary());
        assert_eq!(opt.nodes.len(), 4);
    }

    #[test]
    fn avgpool_head_keeps_relu_over_preactivation_pool() {
        // conv WITHOUT activation → avg → relu: the pool averages
        // possibly-negative values, so the relu is load-bearing.
        let mut n = Network::new("gap_preact");
        let inp = n.input(8, 3);
        let c1 = n.engine(conv_no_act("c1", 8, 3, 4), inp);
        let gap = n.engine(LayerSpec::avgpool("gap", 8, 1, 8, 4), c1);
        let r = n.relu("r", gap);
        n.softmax("prob", r);
        let (opt, _) = run_pipeline(&n);
        opt.check().unwrap();
        assert!(opt.find("r").is_some(), "pre-activation gap head: relu must survive");

        // Non-conv producer (maxpool → avg → relu) is also left alone —
        // the pass only claims the conv adjacency it can prove from the
        // commands (max(0,·) ≥ 0 would be safe too, but stays out of
        // scope; see ROADMAP).
        let mut m = Network::new("gap_maxsrc");
        let inp = m.input(8, 4);
        let p = m.engine(LayerSpec::maxpool("p", 2, 2, 8, 4), inp);
        let gap = m.engine(LayerSpec::avgpool("gap", 4, 1, 4, 4), p);
        let r = m.relu("r", gap);
        m.softmax("prob", r);
        let (opt, _) = run_pipeline(&m);
        assert!(opt.find("r").is_some());
    }

    #[test]
    fn avgpool_head_folds_through_fixpoint_fusion() {
        // conv (standalone relu) → gap → relu: round 1 fuses the inner
        // relu into the conv; round 2's fold_avgpool_head then sees an
        // activated conv under the gap and drops the trailing relu —
        // the fixpoint chaining the pass table promises.
        let mut n = Network::new("gap_chain");
        let inp = n.input(8, 3);
        let c1 = n.engine(conv_no_act("c1", 8, 3, 4), inp);
        let r1 = n.relu("r1", c1);
        let gap = n.engine(LayerSpec::avgpool("gap", 8, 1, 8, 4), r1);
        let r2 = n.relu("r2", gap);
        n.softmax("prob", r2);
        let (opt, report) = run_pipeline(&n);
        opt.check().unwrap();
        assert!(opt.find("r1").is_none() && opt.find("r2").is_none());
        assert!(!engine_spec(&opt, "c1").skip_relu);
        assert_eq!(report.total_changes(), 2);
        assert_eq!(opt.nodes.len(), 4);
    }

    #[test]
    fn idle_and_dead_nodes_are_stripped() {
        let mut n = Network::new("t");
        let inp = n.input(8, 3);
        let c1 = n.engine(LayerSpec::conv("c1", 3, 1, 1, 8, 3, 4, 0), inp);
        // An Idle engine node (would desync the CSB if loaded).
        let mut idle = LayerSpec::conv("skip", 1, 1, 0, 8, 4, 4, 0);
        idle.op = OpType::Idle;
        let id = n.engine(idle, c1);
        // A dead branch: computed, never consumed.
        n.engine(LayerSpec::conv("dead", 1, 1, 0, 8, 4, 16, 0), c1);
        let gap = n.engine(LayerSpec::avgpool("gap", 8, 1, 8, 4), id);
        n.softmax("prob", gap);

        let (opt, report) = run_pipeline(&n);
        opt.check().unwrap();
        assert!(opt.find("skip").is_none());
        assert!(opt.find("dead").is_none());
        let names: Vec<_> = opt.engine_layers().iter().map(|s| s.name.clone()).collect();
        assert_eq!(names, vec!["c1", "gap"]);
        assert_eq!(report.total_changes(), 2);
        assert!(report.summary().contains("strip_idle×1"));
        assert!(report.summary().contains("eliminate_dead×1"));
    }

    #[test]
    fn retag_stamps_two_way_and_four_way_conventions() {
        // 2-way concat with both branches left at slot 0 (a lazy
        // front-end): retagged to the fire-module 1/5 pair.
        let mut n = Network::new("fire_untagged");
        let inp = n.input(8, 3);
        let e1 = n.engine(LayerSpec::conv("e1", 1, 1, 0, 8, 3, 4, 0), inp);
        let e3 = n.engine(LayerSpec::conv("e3", 3, 1, 1, 8, 3, 4, 0), inp);
        let cat = n.concat("cat", vec![e1, e3]);
        n.softmax("prob", cat);
        let (opt, report) = run_pipeline(&n);
        opt.check().unwrap();
        assert_eq!(engine_spec(&opt, "e1").slot, 1);
        assert_eq!(engine_spec(&opt, "e3").slot, 5);
        assert!(report.summary().contains("retag_concat_slots×2"), "{}", report.summary());

        // 4-way inception-style concat: GoogLeNet's builder leaves all
        // branch tips at 0; the convention is (4-1)<<2 | pos = 12..15.
        let g = crate::net::googlenet::googlenet();
        let (opt, report) = run_pipeline(&g);
        opt.check().unwrap();
        assert!(report.summary().contains("retag_concat_slots"), "{}", report.summary());
        for node in &opt.nodes {
            let Node::Concat { inputs, .. } = node else { continue };
            assert_eq!(inputs.len(), 4);
            for (pos, &j) in inputs.iter().enumerate() {
                let Node::Engine { spec, .. } = &opt.nodes[j] else { panic!("non-engine branch") };
                assert_eq!(spec.slot, (3 << 2) | pos as u32, "branch {pos} of some inception");
            }
        }
        // Fixpoint: a second pipeline run changes nothing.
        let (_, again) = run_pipeline(&opt);
        assert_eq!(again.total_changes(), 0);
    }

    #[test]
    fn retag_skips_shared_branches() {
        // e1 feeds the concat AND a second conv: it belongs to no single
        // concat, so its slot must be left alone.
        let mut n = Network::new("shared_branch");
        let inp = n.input(8, 3);
        let e1 = n.engine(LayerSpec::conv("e1", 1, 1, 0, 8, 3, 4, 0), inp);
        let e3 = n.engine(LayerSpec::conv("e3", 3, 1, 1, 8, 3, 4, 0), inp);
        let cat = n.concat("cat", vec![e1, e3]);
        let side = n.engine(LayerSpec::conv("side", 1, 1, 0, 8, 4, 8, 0), e1);
        let cat2 = n.concat("cat2", vec![cat, side]);
        n.softmax("prob", cat2);
        let (opt, _) = run_pipeline(&n);
        opt.check().unwrap();
        assert_eq!(engine_spec(&opt, "e1").slot, 0, "shared branch must keep its tag");
        assert_eq!(engine_spec(&opt, "e3").slot, 0, "partner of a shared branch too");
    }

    #[test]
    fn clean_graph_is_untouched() {
        let net = crate::net::squeezenet::squeezenet_v11();
        let fp = super::super::artifact::graph_fingerprint(&net);
        let (opt, report) = run_pipeline(&net);
        assert_eq!(report.total_changes(), 0);
        assert_eq!(report.summary(), "no-op");
        assert_eq!(super::super::artifact::graph_fingerprint(&opt), fp);
        assert_eq!(opt.nodes.len(), net.nodes.len());
    }
}
