//! Oracle cost model: exact replay of both drivers' transfer and engine
//! accounting from layer shapes alone — no device, no weights, no data.
//!
//! [`crate::perfmodel`] estimates *time* from closed forms; this module
//! predicts the **counters**: per-layer engine passes, cycles, weight
//! loads/reuses, link bytes and link transactions, for any supported
//! network, either driver (single-image vs batched), any batch size, and
//! both residency states (cold first forward vs warm repeat of the same
//! artifact). The contract is *exactness*, pinned by property tests:
//! every number here must equal the [`crate::accel::stream::EngineStats`]
//! / [`crate::telemetry::LayerStat`] counters a real forward measures —
//! predict-then-verify, not estimate-then-hope.
//!
//! Because the prediction is exact, it can drive decisions that used to
//! be heuristic:
//!
//! * [`super::layout`] enumerates the *legal* slicing granularities per
//!   conv and picks the argmin-modeled-cost one ([`conv_layer_cost`]);
//! * [`super::compile`] stamps the modeled cold single-image cost onto
//!   the artifact ([`super::CompiledStream::modeled`]) so the serving
//!   deadline predictor has evidence for networks it has never run;
//! * `fusionaccel explain <net>` prints the modeled-vs-measured table.
//!
//! The model mirrors the drivers loop for loop (the same block / row /
//! pixel / chunk traversal, the same RESFIFO pending-drain placement),
//! but touches only counters — no FP16 math, no cache contents — so it
//! runs in microseconds at compile time.

use crate::host::gemm::{self, ConvGranularity};
use crate::hw::clock::ClockDomain;
use crate::hw::usb::UsbLink;
use crate::net::graph::Network;
use crate::net::layer::{LayerSpec, OpType};

use super::artifact::EpochPlan;

/// Device state the forward starts from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    /// First forward of this artifact: every command stream and weight
    /// super-block crosses the link.
    Cold,
    /// Immediate repeat of the same artifact on the same device: the
    /// command shadow and (for resident weight plans) every keyed weight
    /// super-block are still in place.
    Warm,
}

/// Whether a conv super-block's weights cross the link or hit the
/// device-side shadow. Cold planned loads and unplanned loads produce
/// byte-identical traffic (same transfers, same counters), so the model
/// needs only this binary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum WeightTraffic {
    /// Keyed super-block still resident: zero bytes, one `weight_reuses`.
    Resident,
    /// Full load: one `weight_loads`, weights + bias PipeIn transfers.
    Load,
}

/// Predicted counters for one engine layer (or the command preamble).
/// Field-for-field comparable with [`crate::telemetry::LayerStat`] and
/// the [`crate::accel::stream::EngineStats`] deltas.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LayerCost {
    pub name: String,
    /// Engine passes (`restart_engine` pulses).
    pub passes: u64,
    /// Engine-clock cycles (closed-form, identical to the device model).
    pub cycles: u64,
    /// Weight super-block load transfers.
    pub weight_loads: u64,
    /// Weight super-blocks found resident under their content key.
    pub weight_reuses: u64,
    /// Link bytes (PipeIn + WireOut + PipeOut).
    pub link_bytes: u64,
    /// Link transactions (each pays the per-transaction latency).
    pub link_txns: u64,
}

impl LayerCost {
    fn named(name: &str) -> LayerCost {
        LayerCost { name: name.to_string(), ..LayerCost::default() }
    }

    /// One PipeIn transfer of `values` FP16 values (each crosses as a
    /// 32-bit word — data, weight and bias caches all pay 4 bytes per
    /// value).
    fn pipe_in(&mut self, values: u64) {
        self.link_bytes += 4 * values;
        self.link_txns += 1;
    }

    /// One WireOut interrupt check + one PipeOut of `n` results.
    fn read_results(&mut self, n: u64) {
        self.link_bytes += 4 + 4 * n;
        self.link_txns += 2;
    }

    /// One conv engine pass (serialized-round slice timing:
    /// `3k² + 26` cycles per (output element, channel group) round).
    fn conv_pass(&mut self, out_cols: u64, n_oc: u64, groups: u64, k: u64) {
        self.passes += 1;
        self.cycles += out_cols * n_oc * groups * (3 * k * k + 26);
    }

    /// One pool engine pass: II-2 per window element actually read
    /// (clipped elements are skipped) plus a per-column drain tail.
    #[allow(clippy::too_many_arguments)]
    fn pool_pass(
        &mut self,
        op: OpType,
        out_cols: u64,
        data_rows: u64,
        k: u64,
        stride: u64,
        pool_pad: u64,
        data_width: u64,
    ) {
        self.passes += 1;
        let mut elems = 0u64;
        for xo in 0..out_cols {
            for kx in 0..k {
                let x = xo * stride + kx;
                if x >= pool_pad && x - pool_pad < data_width {
                    elems += data_rows;
                }
            }
        }
        let tail = if op == OpType::AvgPool { 6 } else { 4 };
        self.cycles += elems * 2 + out_cols * tail;
    }

    fn add(&mut self, other: &LayerCost) {
        self.passes += other.passes;
        self.cycles += other.cycles;
        self.weight_loads += other.weight_loads;
        self.weight_reuses += other.weight_reuses;
        self.link_bytes += other.link_bytes;
        self.link_txns += other.link_txns;
    }

    /// Modeled wall time of this layer over `link`: engine compute plus
    /// link time (per-transaction latency + bytes over bandwidth) —
    /// exactly the terms `ForwardResult::whole_process_seconds` sums.
    pub fn seconds(&self, link: &UsbLink) -> f64 {
        ClockDomain::ENGINE.secs(self.cycles)
            + self.link_txns as f64 * link.txn_latency
            + self.link_bytes as f64 / link.bandwidth
    }
}

/// Predicted cost of one whole forward (single-image or batched) of a
/// compiled stream.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StreamCost {
    /// Images per forward this prediction models (1 = single driver,
    /// ≥ 2 = batched driver — the dispatch rule the serving worker uses).
    pub batch: usize,
    pub residency: Residency,
    /// Epoch-0 command transfer. It happens before the first
    /// `load_layer`, so it falls *outside* every layer-tape delta —
    /// modeled separately so per-layer rows still match exactly.
    pub preamble: LayerCost,
    /// Per engine layer, in engine order (indexed like
    /// `net.engine_layers()`).
    pub layers: Vec<LayerCost>,
    /// Command streams that crossed the link.
    pub command_loads: u64,
    /// Command streams replayed from the device shadow (zero bytes).
    pub command_reuses: u64,
}

impl StreamCost {
    /// Sum of the preamble and every layer.
    pub fn total(&self) -> LayerCost {
        let mut t = LayerCost::named("total");
        t.add(&self.preamble);
        for l in &self.layers {
            t.add(l);
        }
        t
    }

    /// Modeled whole-forward seconds over `link` (engine + link).
    pub fn seconds(&self, link: &UsbLink) -> f64 {
        self.total().seconds(link)
    }

    /// Modeled service seconds per image.
    pub fn per_image_seconds(&self, link: &UsbLink) -> f64 {
        self.seconds(link) / self.batch.max(1) as f64
    }
}

/// Predict the cost of forwarding `batch` images through a compiled
/// stream from `residency` state. `batch == 1` models
/// [`crate::host::driver::HostDriver::forward_compiled`]; `batch ≥ 2`
/// models [`crate::host::batch::forward_batch_compiled`] — the same
/// split the serving worker dispatches on.
pub fn stream_cost(
    cs: &super::CompiledStream,
    batch: usize,
    residency: Residency,
) -> StreamCost {
    model_stream(
        &cs.net,
        &cs.epochs,
        cs.weight_plan.is_resident(),
        &cs.granularities,
        batch,
        residency,
    )
}

/// Parts-level model entry point: everything [`stream_cost`] needs,
/// before a [`super::CompiledStream`] exists — `compile` calls this to
/// stamp the modeled cost onto the artifact it is constructing.
pub(crate) fn model_stream(
    net: &Network,
    epochs: &[EpochPlan],
    plan_resident: bool,
    granularities: &[Option<ConvGranularity>],
    batch: usize,
    residency: Residency,
) -> StreamCost {
    let layers = net.engine_layers();
    let wt = if plan_resident && residency == Residency::Warm {
        WeightTraffic::Resident
    } else {
        WeightTraffic::Load
    };
    let mut out = StreamCost {
        batch,
        residency,
        preamble: LayerCost::named("commands"),
        layers: layers.iter().map(|s| LayerCost::named(&s.name)).collect(),
        command_loads: 0,
        command_reuses: 0,
    };

    // Command epochs. Only single-epoch streams keep a stable shadow key
    // across forwards (multi-epoch keys rotate through the one shadow
    // slot, so a warm repeat still reloads every epoch). Epoch `e ≥ 1`
    // loads after the previous layer's `load_layer` and before this
    // epoch's first, so its traffic lands in the *previous* layer's
    // tape delta; epoch 0 precedes every mark.
    let warm_shadow = residency == Residency::Warm && epochs.len() == 1;
    for (e, ep) in epochs.iter().enumerate() {
        let target = if e == 0 {
            &mut out.preamble
        } else {
            &mut out.layers[ep.start - 1]
        };
        if warm_shadow {
            out.command_reuses += 1;
        } else {
            out.command_loads += 1;
            target.pipe_in(3 * ep.len as u64); // 12 bytes per command
        }
    }

    for (eidx, spec) in layers.iter().enumerate() {
        let cost = &mut out.layers[eidx];
        match spec.op {
            OpType::ConvRelu => {
                let gran = granularities.get(eidx).copied().flatten().unwrap_or_else(|| {
                    let icp = (spec.i_ch as usize).div_ceil(8) * 8;
                    let pw = (spec.i_side + 2 * spec.padding) as usize;
                    gemm::conv_granularity(spec.kernel as usize, pw, icp)
                });
                conv_cost(cost, spec, gran, wt, batch);
            }
            OpType::MaxPool | OpType::AvgPool => pool_cost(cost, spec, batch),
            OpType::Idle => {} // no device traffic, no engine work
        }
    }
    out
}

/// Modeled cost of one conv layer in isolation, cold and unplanned —
/// the figure of merit the layout pass minimizes over legal candidate
/// granularities. (Weight traffic is granularity-independent, but it is
/// included so the returned cost is a complete layer prediction.)
pub fn conv_layer_cost(spec: &LayerSpec, gran: ConvGranularity, batch: usize) -> LayerCost {
    let mut cost = LayerCost::named(&spec.name);
    conv_cost(&mut cost, spec, gran, WeightTraffic::Load, batch);
    cost
}

/// Chunk lengths of `n` items grouped by `per` (mirrors
/// `slice::chunks`): the image-group sizes both batched drivers iterate.
fn group_sizes(n: usize, per: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(n.div_ceil(per));
    let mut left = n;
    while left > 0 {
        let take = per.min(left);
        out.push(take);
        left -= take;
    }
    out
}

/// Conv layer cost, replaying `HostDriver::run_conv` (batch == 1) or
/// `batch::conv_batch` (batch ≥ 2) loop for loop.
fn conv_cost(
    cost: &mut LayerCost,
    spec: &LayerSpec,
    gran: ConvGranularity,
    wt: WeightTraffic,
    batch: usize,
) {
    let k = spec.kernel as usize;
    let o = spec.o_side as usize;
    let o_ch = spec.o_ch as usize;
    let icp = (spec.i_ch as usize).div_ceil(8) * 8;
    let groups = icp / 8;
    let pw = (spec.i_side + 2 * spec.padding) as usize;
    let layout = gemm::conv_layout(k, spec.i_ch as usize, o_ch);
    let cc = (gran == ConvGranularity::ChannelSplit).then(|| gemm::channel_chunks(k, icp));

    // One pending-results counter models RESFIFO occupancy exactly: each
    // pass pushes its results, a drain empties it in one WireOut+PipeOut.
    let mut pending = 0u64;
    macro_rules! drain {
        () => {
            if pending > 0 {
                cost.read_results(pending);
                pending = 0;
            }
        };
    }
    let space = |pending: u64| gemm::RES_FIFO_VALUES as u64 - pending;

    let slice_words = match gran {
        ConvGranularity::Row => k * pw * icp / 8,
        ConvGranularity::Pixel | ConvGranularity::ChannelSplit => k * k * icp / 8,
    };
    let imgs_per_load =
        (crate::accel::stream::DATA_CACHE_WORDS / slice_words.max(1)).clamp(1, batch);

    let mut oc0 = 0usize;
    while oc0 < o_ch {
        let resident = layout.super_block.min(o_ch - oc0);
        match wt {
            WeightTraffic::Resident => cost.weight_reuses += 1,
            WeightTraffic::Load => {
                cost.weight_loads += 1;
                cost.pipe_in((resident * layout.per_oc_values) as u64);
                cost.pipe_in(resident as u64); // bias block
            }
        }
        // Output-channel pass steps within the resident block.
        let oc_steps: Vec<usize> = group_sizes(resident, layout.oc_pass);

        match gran {
            ConvGranularity::Row => {
                if batch == 1 {
                    for _y in 0..o {
                        cost.pipe_in((k * pw * icp) as u64);
                        for &n_oc in &oc_steps {
                            cost.conv_pass(o as u64, n_oc as u64, groups as u64, k as u64);
                            cost.read_results((o * n_oc) as u64);
                        }
                    }
                } else {
                    for _y in 0..o {
                        for &chunk_len in &group_sizes(batch, imgs_per_load) {
                            cost.pipe_in((chunk_len * slice_words * 8) as u64);
                            for _ci in 0..chunk_len {
                                for &n_oc in &oc_steps {
                                    let n_results = (o * n_oc) as u64;
                                    if space(pending) < n_results {
                                        drain!();
                                    }
                                    cost.conv_pass(o as u64, n_oc as u64, groups as u64, k as u64);
                                    pending += n_results;
                                }
                            }
                            drain!();
                        }
                    }
                }
            }
            ConvGranularity::Pixel => {
                if batch == 1 {
                    for _px in 0..o * o {
                        cost.pipe_in((k * k * icp) as u64);
                        for &n_oc in &oc_steps {
                            cost.conv_pass(1, n_oc as u64, groups as u64, k as u64);
                            cost.read_results(n_oc as u64);
                        }
                    }
                } else {
                    for _px in 0..o * o {
                        for &chunk_len in &group_sizes(batch, imgs_per_load) {
                            cost.pipe_in((chunk_len * slice_words * 8) as u64);
                            for _ci in 0..chunk_len {
                                for &n_oc in &oc_steps {
                                    if space(pending) < n_oc as u64 {
                                        drain!();
                                    }
                                    cost.conv_pass(1, n_oc as u64, groups as u64, k as u64);
                                    pending += n_oc as u64;
                                }
                            }
                            drain!();
                        }
                    }
                }
            }
            ConvGranularity::ChannelSplit => {
                let cc = cc.as_ref().unwrap();
                if batch == 1 {
                    for _px in 0..o * o {
                        for c in 0..cc.count {
                            let (_g0, gn) = cc.chunk(c);
                            cost.pipe_in((k * k * gn * 8) as u64);
                            for &n_oc in &oc_steps {
                                if c > 0 {
                                    cost.pipe_in(n_oc as u64); // partial re-entry via bias port
                                }
                                cost.conv_pass(1, n_oc as u64, gn as u64, k as u64);
                                cost.read_results(n_oc as u64);
                            }
                        }
                    }
                } else {
                    for _px in 0..o * o {
                        for c in 0..cc.count {
                            let (_g0, gn) = cc.chunk(c);
                            let cw = cc.slice_words(c);
                            let per = (crate::accel::stream::DATA_CACHE_WORDS / cw).clamp(1, batch);
                            for &group_len in &group_sizes(batch, per) {
                                cost.pipe_in((group_len * cw * 8) as u64);
                                for _ci in 0..group_len {
                                    for &n_oc in &oc_steps {
                                        if space(pending) < n_oc as u64 {
                                            drain!();
                                        }
                                        if c > 0 {
                                            cost.pipe_in(n_oc as u64);
                                        }
                                        cost.conv_pass(1, n_oc as u64, gn as u64, k as u64);
                                        pending += n_oc as u64;
                                    }
                                }
                            }
                            // Chunk barrier: the next chunk re-enters
                            // these partials through the bias port.
                            drain!();
                        }
                    }
                }
            }
        }
        oc0 += resident;
    }
    debug_assert_eq!(pending, 0);
}

/// Pool layer cost, replaying `HostDriver::run_pool` /
/// `run_giant_maxpool` (batch == 1) or `batch::pool_batch` /
/// `giant_maxpool_batch` (batch ≥ 2).
fn pool_cost(cost: &mut LayerCost, spec: &LayerSpec, batch: usize) {
    let k = spec.kernel as usize;
    let s = spec.stride as usize;
    let o = spec.o_side as usize;
    let pad = spec.padding as usize;
    let ih = spec.i_side as usize;
    let groups = (spec.i_ch as usize).div_ceil(8);

    let mut pending = 0u64;
    macro_rules! drain {
        () => {
            if pending > 0 {
                cost.read_results(pending);
                pending = 0;
            }
        };
    }
    let space = |pending: u64| gemm::RES_FIFO_VALUES as u64 - pending;

    if k * k > crate::accel::stream::DATA_CACHE_WORDS {
        // Giant window (max only — the drivers reject giant avg).
        for _g in 0..groups {
            for y in 0..o {
                let y0 = (y * s).saturating_sub(pad);
                let rows = (y * s + k - pad).min(ih) - y0;
                for x in 0..o {
                    let c0 = (x * s).saturating_sub(pad);
                    let width = (x * s + k - pad).min(ih) - c0;
                    let cpad = pad.saturating_sub(x * s);
                    for rc in gemm::pool_row_chunks(rows, width) {
                        if batch == 1 {
                            cost.pipe_in((rc.rows * width * 8) as u64);
                            cost.pool_pass(
                                spec.op,
                                1,
                                rc.rows as u64,
                                k as u64,
                                s as u64,
                                cpad as u64,
                                width as u64,
                            );
                            cost.read_results(8);
                        } else {
                            let slice_words = rc.rows * width;
                            let per = (crate::accel::stream::DATA_CACHE_WORDS / slice_words)
                                .clamp(1, batch);
                            for &group_len in &group_sizes(batch, per) {
                                cost.pipe_in((group_len * slice_words * 8) as u64);
                                for _ci in 0..group_len {
                                    if space(pending) < 8 {
                                        drain!();
                                    }
                                    cost.pool_pass(
                                        spec.op,
                                        1,
                                        rc.rows as u64,
                                        k as u64,
                                        s as u64,
                                        cpad as u64,
                                        width as u64,
                                    );
                                    pending += 8;
                                }
                                drain!();
                            }
                        }
                    }
                }
            }
        }
        return;
    }

    let chunks = gemm::pool_col_chunks(k, s, pad, ih, o);
    for _g in 0..groups {
        for y in 0..o {
            let y0 = (y * s).saturating_sub(pad);
            let rows = (y * s + k - pad).min(ih) - y0;
            for ch in &chunks {
                if batch == 1 {
                    cost.pipe_in((rows * ch.width * 8) as u64);
                    cost.pool_pass(
                        spec.op,
                        ch.cols as u64,
                        rows as u64,
                        k as u64,
                        s as u64,
                        ch.pad as u64,
                        ch.width as u64,
                    );
                    cost.read_results((ch.cols * 8) as u64);
                } else {
                    let slice_words = rows * ch.width;
                    let per =
                        (crate::accel::stream::DATA_CACHE_WORDS / slice_words).clamp(1, batch);
                    for &chunk_len in &group_sizes(batch, per) {
                        cost.pipe_in((chunk_len * slice_words * 8) as u64);
                        for _ci in 0..chunk_len {
                            let n_results = (ch.cols * 8) as u64;
                            if space(pending) < n_results {
                                drain!();
                            }
                            cost.pool_pass(
                                spec.op,
                                ch.cols as u64,
                                rows as u64,
                                k as u64,
                                s as u64,
                                ch.pad as u64,
                                ch.width as u64,
                            );
                            pending += n_results;
                        }
                        drain!();
                    }
                }
            }
        }
    }
    debug_assert_eq!(pending, 0);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_sizes_mirror_slice_chunks() {
        assert_eq!(group_sizes(7, 3), vec![3, 3, 1]);
        assert_eq!(group_sizes(4, 4), vec![4]);
        assert_eq!(group_sizes(3, 8), vec![3]);
        assert!(group_sizes(0, 5).is_empty());
    }

    #[test]
    fn row_beats_pixel_when_both_legal() {
        // SqueezeNet conv1 shape: both row (5448 values) and pixel (72)
        // slices fit; row loads per output row, pixel per output pixel —
        // 113× more transactions. Link latency dominates.
        let spec = LayerSpec::conv("conv1", 3, 2, 0, 227, 3, 64, 0);
        let row = conv_layer_cost(&spec, ConvGranularity::Row, 1);
        let pixel = conv_layer_cost(&spec, ConvGranularity::Pixel, 1);
        assert!(row.link_txns < pixel.link_txns);
        let usb = UsbLink::usb3_frontpanel();
        assert!(row.seconds(&usb) < pixel.seconds(&usb));
        // Engine work is granularity-independent: same macs, same cycles.
        assert_eq!(row.cycles, pixel.cycles);
        assert_eq!(row.passes * 113, pixel.passes);
    }

    #[test]
    fn channel_split_with_one_chunk_equals_pixel() {
        // A window small enough for one chunk: the split path degenerates
        // to the pixel path — identical counters, so argmin ties and
        // first-fit order (pixel first) breaks the tie.
        let spec = LayerSpec::conv("c", 5, 1, 2, 14, 96, 16, 0);
        let cc = gemm::channel_chunks(5, 96);
        assert_eq!(cc.count, 1);
        let split = conv_layer_cost(&spec, ConvGranularity::ChannelSplit, 1);
        let pixel = conv_layer_cost(&spec, ConvGranularity::Pixel, 1);
        assert_eq!(
            LayerCost { name: String::new(), ..split },
            LayerCost { name: String::new(), ..pixel }
        );
    }

    #[test]
    fn batching_amortizes_weight_loads_in_the_model() {
        let spec = LayerSpec::conv("c1", 3, 1, 0, 12, 3, 8, 0);
        let one = conv_layer_cost(&spec, ConvGranularity::Row, 1);
        let b4 = conv_layer_cost(&spec, ConvGranularity::Row, 4);
        // Same weight transfers for 4 images as for 1…
        assert_eq!(b4.weight_loads, one.weight_loads);
        // …and 4× the engine work.
        assert_eq!(b4.cycles, 4 * one.cycles);
        assert_eq!(b4.passes, 4 * one.passes);
        // Fewer than 4× the transactions (coalesced slabs + drains).
        assert!(b4.link_txns < 4 * one.link_txns);
    }
}
