//! Artifact registry and model repo — where compiled command streams
//! live between requests.
//!
//! [`ArtifactRegistry`] memoizes [`compile`] by a hash of the *source*
//! graph + weights identity, so re-registering an unchanged network (or
//! the same network arriving from a different front-end instance) costs
//! one map lookup. [`ModelRepo`] is the serving-side view: named,
//! immutable entries of (compiled stream, weights) that a worker pool
//! shares by reference and reconfigures from per batch.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Result};

use crate::net::graph::Network;
use crate::net::weights::Blobs;

use super::artifact::{combine, compile, fnv1a, graph_fingerprint, CompiledStream};
use super::verify;

/// Compile memo keyed by `combine(graph_fingerprint(source), weights_id)`.
#[derive(Debug, Default)]
pub struct ArtifactRegistry {
    memo: Mutex<HashMap<u64, Arc<CompiledStream>>>,
    compiles: AtomicU64,
    hits: AtomicU64,
}

impl ArtifactRegistry {
    pub fn new() -> ArtifactRegistry {
        ArtifactRegistry::default()
    }

    /// Return the compiled stream for `net` + `weights_id`, compiling
    /// at most once per distinct source.
    pub fn get_or_compile(&self, net: &Network, weights_id: u64) -> Result<Arc<CompiledStream>> {
        let key = combine(graph_fingerprint(net), weights_id);
        let mut memo = self.memo.lock().unwrap();
        if let Some(found) = memo.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(found.clone());
        }
        let stream = Arc::new(compile(net, weights_id)?);
        memo.insert(key, stream.clone());
        self.compiles.fetch_add(1, Ordering::Relaxed);
        Ok(stream)
    }

    /// Compilations actually performed.
    pub fn compiles(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    /// Memo hits (source graph + weights already compiled).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Distinct artifacts held.
    pub fn len(&self) -> usize {
        self.memo.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One servable network: its compiled stream and the weights it binds.
#[derive(Clone, Debug)]
pub struct ServableModel {
    /// Registration name (the network's name).
    pub name: String,
    pub stream: Arc<CompiledStream>,
    pub blobs: Blobs,
}

/// Named, immutable model set for a serving run. Built up front, then
/// shared by reference across the worker pool — workers resolve a
/// request's `network` name here and cache the `Arc` handles in their
/// per-worker LRU.
#[derive(Debug, Default)]
pub struct ModelRepo {
    registry: ArtifactRegistry,
    by_name: HashMap<String, Arc<ServableModel>>,
    /// First registered model — what untagged requests resolve to.
    default: Option<String>,
}

impl ModelRepo {
    pub fn new() -> ModelRepo {
        ModelRepo::default()
    }

    /// Compile and register `net` under its own name. The weights
    /// identity is derived from the FAWB byte serialization, so the
    /// artifact id changes iff the graph or the weights change.
    /// Returns the artifact id.
    pub fn register(&mut self, net: Network, blobs: Blobs) -> Result<String> {
        ensure!(
            !self.by_name.contains_key(&net.name),
            "model {:?} already registered",
            net.name
        );
        let weights_id = fnv1a(&blobs.to_bytes());
        let stream = self.registry.get_or_compile(&net, weights_id)?;
        let id = stream.id.clone();
        let name = net.name.clone();
        if self.default.is_none() {
            self.default = Some(name.clone());
        }
        self.by_name.insert(name.clone(), Arc::new(ServableModel { name, stream, blobs }));
        Ok(id)
    }

    /// Resolve a request's network tag to a registered name (`None` →
    /// the default model).
    pub fn resolve(&self, network: Option<&str>) -> Result<String> {
        match network {
            Some(name) => {
                ensure!(self.by_name.contains_key(name), "unknown network {name:?}");
                Ok(name.to_string())
            }
            None => match &self.default {
                Some(name) => Ok(name.clone()),
                None => bail!("no models registered"),
            },
        }
    }

    /// Register a pre-compiled artifact directly, bypassing the compile
    /// path. Only a duplicate-name check happens here — the artifact's
    /// verification status is *not* re-checked at registration, because
    /// the serving gate is [`Self::serveable`]: every worker admission
    /// re-proves the seal, so an unverified or since-mutated artifact
    /// can sit in the repo but never reaches an engine.
    pub fn register_artifact(
        &mut self,
        name: &str,
        stream: Arc<CompiledStream>,
        blobs: Blobs,
    ) -> Result<()> {
        ensure!(!self.by_name.contains_key(name), "model {name:?} already registered");
        if self.default.is_none() {
            self.default = Some(name.to_string());
        }
        self.by_name
            .insert(name.to_string(), Arc::new(ServableModel { name: name.to_string(), stream, blobs }));
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<Arc<ServableModel>> {
        self.by_name.get(name).cloned()
    }

    /// The serve-time verification gate: resolve `name` and prove the
    /// artifact's stamped seal still matches its content
    /// ([`verify::artifact_seal`]). An unknown name, an unverified
    /// artifact (`seal == 0` never matches — the seal hashes a non-empty
    /// domain tag), or one mutated after compilation all fail here, so a
    /// worker can never reconfigure an engine from a stream the static
    /// verifier hasn't passed.
    pub fn serveable(&self, name: &str) -> Result<Arc<ServableModel>> {
        let Some(model) = self.get(name) else {
            bail!("unknown network {name:?}");
        };
        let want = verify::artifact_seal(&model.stream);
        ensure!(
            model.stream.seal == want,
            "artifact {} for network {name:?} fails the serve-time verification gate \
             ({}: stamped seal {:016x}, content {want:016x})",
            model.stream.id,
            verify::FA_SEAL_STALE,
            model.stream.seal,
        );
        Ok(model)
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    /// Registered model names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.by_name.keys().cloned().collect();
        names.sort();
        names
    }

    /// The underlying compile memo (for reuse stats).
    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    /// Cheap serving snapshot: shares every registered model by `Arc`
    /// (no artifact or weight copies) under a fresh, empty compile
    /// memo. This is what a long-lived [`crate::service::Service`] pins
    /// for its whole lifetime while the caller keeps mutating — or just
    /// keeps — the original repo.
    pub fn snapshot(&self) -> ModelRepo {
        ModelRepo {
            registry: ArtifactRegistry::new(),
            by_name: self.by_name.clone(),
            default: self.default.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::layer::LayerSpec;
    use crate::net::weights::synthesize_weights;

    fn tiny(name: &str) -> Network {
        let mut n = Network::new(name);
        let inp = n.input(8, 3);
        let c1 = n.engine(LayerSpec::conv("c1", 3, 1, 0, 8, 3, 8, 0), inp);
        let gap = n.engine(LayerSpec::avgpool("gap", 6, 1, 6, 8), c1);
        n.softmax("prob", gap);
        n
    }

    #[test]
    fn registry_memoizes_compiles() {
        let reg = ArtifactRegistry::new();
        let net = tiny("t");
        let a = reg.get_or_compile(&net, 7).unwrap();
        let b = reg.get_or_compile(&net, 7).unwrap();
        assert_eq!(a.id, b.id);
        assert_eq!(reg.compiles(), 1);
        assert_eq!(reg.hits(), 1);
        // Different weights identity → different artifact.
        let c = reg.get_or_compile(&net, 8).unwrap();
        assert_ne!(a.id, c.id);
        assert_eq!(reg.compiles(), 2);
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn repo_registers_resolves_and_defaults() {
        let mut repo = ModelRepo::new();
        let net_a = tiny("alpha");
        let blobs_a = synthesize_weights(&net_a, 1);
        let net_b = tiny("beta");
        let blobs_b = synthesize_weights(&net_b, 2);
        let id_a = repo.register(net_a, blobs_a).unwrap();
        let id_b = repo.register(net_b, blobs_b).unwrap();
        // Same graph shape, different weights → different artifacts.
        assert_ne!(id_a, id_b);
        assert_eq!(repo.len(), 2);
        assert_eq!(repo.names(), vec!["alpha".to_string(), "beta".to_string()]);
        assert_eq!(repo.resolve(None).unwrap(), "alpha");
        assert_eq!(repo.resolve(Some("beta")).unwrap(), "beta");
        assert!(repo.resolve(Some("ghost")).is_err());
        assert!(repo.get("alpha").is_some());
        assert!(repo.get("ghost").is_none());
    }

    #[test]
    fn duplicate_registration_is_rejected() {
        let mut repo = ModelRepo::new();
        let net = tiny("dup");
        let blobs = synthesize_weights(&net, 1);
        repo.register(net.clone(), blobs.clone()).unwrap();
        assert!(repo.register(net, blobs).is_err());
    }

    #[test]
    fn snapshot_shares_models_under_a_fresh_memo() {
        let mut repo = ModelRepo::new();
        let net = tiny("snap");
        repo.register(net, synthesize_weights(&tiny("snap"), 1)).unwrap();
        let snap = repo.snapshot();
        assert_eq!(snap.names(), repo.names());
        assert_eq!(snap.resolve(None).unwrap(), "snap");
        // Same Arc, not a copy.
        assert!(Arc::ptr_eq(&snap.get("snap").unwrap(), &repo.get("snap").unwrap()));
        // The snapshot's compile memo is its own (and empty).
        assert_eq!(snap.registry().compiles(), 0);
        assert_eq!(repo.registry().compiles(), 1);
    }

    #[test]
    fn serveable_gates_on_the_verification_seal() {
        let mut repo = ModelRepo::new();
        let net = tiny("gated");
        repo.register(net.clone(), synthesize_weights(&net, 1)).unwrap();
        // A compile()-produced artifact passes the gate.
        assert!(repo.serveable("gated").is_ok());
        assert!(repo.serveable("ghost").is_err());

        // A mutated clone of the same artifact: registerable, never
        // serveable — the seal no longer matches the content.
        let mut bent = (*repo.get("gated").unwrap().stream).clone();
        bent.epochs[0].len = 0;
        repo.register_artifact("bent", Arc::new(bent), synthesize_weights(&net, 1)).unwrap();
        assert!(repo.get("bent").is_some(), "registration itself must succeed");
        let err = repo.serveable("bent").unwrap_err().to_string();
        assert!(err.contains("FA-SEAL-STALE"), "{err}");

        // An unverified artifact (seal 0) is equally refused.
        let raw = crate::compiler::compile_unverified(&net, 1).unwrap();
        assert_eq!(raw.seal, 0);
        repo.register_artifact("raw", Arc::new(raw), synthesize_weights(&net, 1)).unwrap();
        assert!(repo.serveable("raw").is_err());
    }

    #[test]
    fn identical_weights_share_the_artifact() {
        // Two names, same graph *and* same weight bytes: one compile,
        // one artifact id — content addressing at work.
        let mut repo = ModelRepo::new();
        let net = tiny("same");
        let blobs = synthesize_weights(&net, 5);
        let id_a = repo.register(net.clone(), blobs.clone()).unwrap();
        let renamed = Network { name: "same2".to_string(), nodes: net.nodes };
        let id_b = repo.register(renamed, blobs).unwrap();
        assert_eq!(id_a, id_b);
        assert_eq!(repo.registry().compiles(), 1);
        assert_eq!(repo.registry().hits(), 1);
    }
}
