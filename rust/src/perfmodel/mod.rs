//! End-to-end timing model (§5): engine cycles + link traffic for a whole
//! network, parametric in parallelism and link, so the S5 experiment can
//! reproduce the paper's measured numbers (10.7 s compute / 40.9 s whole
//! process for SqueezeNet v1.1 at parallelism 8 over USB3.0) and predict
//! the §6.1 what-ifs (more parallelism, PCIe instead of USB).
//!
//! The transfer model replicates the driver's slicing arithmetic
//! analytically (validated against the actual driver's USB counters in
//! `rust/tests/`); engine cycles use the closed form validated against
//! the cycle-accurate simulator in [`crate::engine::timed`].
//!
//! This module answers paper-scale what-ifs (parallelism, link swaps)
//! with closed forms. Its exact counterpart is
//! [`crate::compiler::cost`]: an oracle that predicts the *measured
//! counters* of a compiled stream (passes, weight loads, link
//! bytes/transactions) loop for loop, pinned `modeled == measured` by
//! property tests, and used by the layout argmin and the serving
//! cold-start predictor. Reach for `compiler::cost` when the number
//! must match the device model exactly; reach for this module when
//! sweeping hardware parameters the device model does not have.

use crate::hw::clock::ClockDomain;
use crate::hw::usb::UsbLink;
use crate::net::graph::Network;
use crate::net::layer::{LayerSpec, OpType};

/// Data/weight cache capacities in values, parametric in parallelism
/// (the §4.4 widths scale with `BURST_LEN`).
fn data_cache_values(p: u64) -> u64 {
    1024 * p
}
fn weight_cache_values(p: u64) -> u64 {
    8192 * p
}

/// Per-layer timing/traffic breakdown.
#[derive(Clone, Debug)]
pub struct LayerTiming {
    pub name: String,
    pub engine_cycles: u64,
    /// Bytes moved host→device (weights + bias + data slices).
    pub bytes_in: u64,
    /// Bytes device→host (results as 32-bit words).
    pub bytes_out: u64,
    /// Link transactions (each paying the per-transaction latency).
    pub txns: u64,
}

/// Whole-network timing report.
#[derive(Clone, Debug)]
pub struct TimingReport {
    pub parallelism: u64,
    pub link: UsbLink,
    pub layers: Vec<LayerTiming>,
}

impl TimingReport {
    pub fn engine_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.engine_cycles).sum()
    }

    /// The paper's "computation time" (10.7 s @ P=8).
    pub fn compute_seconds(&self) -> f64 {
        ClockDomain::ENGINE.secs(self.engine_cycles())
    }

    pub fn transfer_seconds(&self) -> f64 {
        let bytes: u64 = self.layers.iter().map(|l| l.bytes_in + l.bytes_out).sum();
        let txns: u64 = self.layers.iter().map(|l| l.txns).sum();
        txns as f64 * self.link.txn_latency + bytes as f64 / self.link.bandwidth
    }

    /// The paper's "whole process" time (40.9 s @ P=8): compute and
    /// transfer do not overlap in the Fig 35/36 flow.
    pub fn whole_process_seconds(&self) -> f64 {
        self.compute_seconds() + self.transfer_seconds()
    }

    pub fn total_txns(&self) -> u64 {
        self.layers.iter().map(|l| l.txns).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.bytes_in + l.bytes_out).sum()
    }
}

/// Engine cycles for one layer at parallelism `p`.
///
/// This is the **serialized-round** model of the shipped RTL (Fig 25's
/// description: "new data should be fed after the accumulators … are
/// finished", i.e. rounds do not overlap): per (output element, channel
/// group) round the engine pays k² multiplier-feed cycles + 6 multiplier
/// latency + 2·k² psum accumulation + 2 psum latency + 2·p fsum chain +
/// 2 = 3·k² + 2·p + 10 cycles. At p = 8 over SqueezeNet v1.1 this lands
/// at ≈ 7.8 s — the same regime as the paper's measured 10.7 s, an order
/// of magnitude above the 8-MAC/cycle bound, exactly as the paper's
/// filled-pipeline remark predicts. (A hypothetical *overlapped* engine
/// is the `engine::timed` simulator, which pipelines rounds through the
/// FIFOs and would cut compute ≈ 2×; see the A-series benches.)
pub fn layer_engine_cycles(spec: &LayerSpec, p: u64) -> u64 {
    let k2 = spec.kernel_size() as u64;
    let o2 = spec.o_side as u64 * spec.o_side as u64;
    let groups = (spec.i_ch as u64).div_ceil(p);
    match spec.op {
        OpType::ConvRelu => o2 * spec.o_ch as u64 * groups * (3 * k2 + 2 * p + 10),
        OpType::MaxPool => o2 * groups * (2 * k2 + 4),
        OpType::AvgPool => o2 * groups * (2 * k2 + 6),
        OpType::Idle => 0,
    }
}

/// Transfer traffic for one layer at parallelism `p` — the driver's
/// slicing arithmetic, analytically:
/// * conv: weights in super-blocks that fit the weight cache; per
///   super-block, one data slice per output row (or per pixel when a row
///   slice exceeds the data cache); engine passes of ≤ p output
///   channels; one result read per pass;
/// * pool: one slice per (channel group, output row).
pub fn layer_traffic(spec: &LayerSpec, p: u64) -> (u64, u64, u64) {
    let k = spec.kernel as u64;
    let o = spec.o_side as u64;
    let lanes = (spec.i_ch as u64).div_ceil(p) * p;
    match spec.op {
        OpType::ConvRelu => {
            let per_oc_values = k * k * lanes;
            let oc_pass = (weight_cache_values(p) / per_oc_values).clamp(1, p);
            let super_block = (weight_cache_values(p) / per_oc_values).max(1).min(spec.o_ch as u64);
            let n_super = (spec.o_ch as u64).div_ceil(super_block);
            let padded_w = spec.i_side as u64 + 2 * spec.padding as u64;
            let row_slice = k * padded_w * lanes;
            let (slices_per_sweep, slice_values, passes_per_slice) =
                if row_slice <= data_cache_values(p) {
                    (o, row_slice, super_block.div_ceil(oc_pass))
                } else {
                    (o * o, k * k * lanes, super_block.div_ceil(oc_pass))
                };

            let weight_bytes = n_super * 4 * (super_block * per_oc_values + super_block);
            let data_bytes = n_super * slices_per_sweep * 4 * slice_values;
            let bytes_in = weight_bytes + data_bytes;
            let result_reads = n_super * slices_per_sweep * passes_per_slice;
            let bytes_out = 4 * o * o * spec.o_ch as u64;
            // txns: per super-block: 2 (weights+bias); per slice: 1 data;
            // per pass: 1 wire-out + 1 pipe-out.
            let txns = n_super * 2 + n_super * slices_per_sweep + 2 * result_reads;
            (bytes_in, bytes_out, txns)
        }
        OpType::MaxPool | OpType::AvgPool => {
            let groups = (spec.i_ch as u64).div_ceil(p);
            let slice_values = k * spec.i_side as u64 * p;
            let slices = groups * o;
            let bytes_in = 4 * slices * slice_values;
            let bytes_out = 4 * o * o * spec.i_ch as u64;
            let txns = slices + 2 * slices;
            (bytes_in, bytes_out, txns)
        }
        OpType::Idle => (0, 0, 0),
    }
}

/// Model a whole network.
pub fn model_network(net: &Network, p: u64, link: UsbLink) -> TimingReport {
    let mut layers = Vec::new();
    for spec in net.engine_layers() {
        let (bytes_in, bytes_out, txns) = layer_traffic(spec, p);
        layers.push(LayerTiming {
            name: spec.name.clone(),
            engine_cycles: layer_engine_cycles(spec, p),
            bytes_in,
            bytes_out,
            txns,
        });
    }
    // Command load: 12 bytes per layer, one transaction.
    if let Some(first) = layers.first_mut() {
        first.bytes_in += 12 * net.engine_layers().len() as u64;
        first.txns += 1;
    }
    TimingReport { parallelism: p, link, layers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::squeezenet::squeezenet_v11;

    #[test]
    fn p8_compute_time_reproduces_paper_magnitude() {
        // Paper §5: computation time 10.7 s at parallelism 8 / 100 MHz.
        // The model must land in the same regime (an order of magnitude
        // above the 0.5 s MAC bound — the accumulator-II effect).
        let net = squeezenet_v11();
        let rep = model_network(&net, 8, UsbLink::usb3_frontpanel());
        let t = rep.compute_seconds();
        assert!(t > 5.0 && t < 16.0, "compute {t:.2}s vs paper 10.7s");
    }

    #[test]
    fn whole_process_exceeds_compute_substantially() {
        // Paper: 40.9 s whole process vs 10.7 s compute — transfers and
        // per-transaction latency dominate. Shape check: whole ≥ 2×.
        let net = squeezenet_v11();
        let rep = model_network(&net, 8, UsbLink::usb3_frontpanel());
        let whole = rep.whole_process_seconds();
        let compute = rep.compute_seconds();
        assert!(whole > 2.0 * compute, "whole {whole:.1}s compute {compute:.1}s");
        assert!(whole > 20.0 && whole < 70.0, "whole {whole:.1}s vs paper 40.9s");
    }

    #[test]
    fn parallelism_scales_compute_down() {
        // §5: "If there are more hardware resource to improve parallelism,
        // the computation time will be proportionally reduced."
        let net = squeezenet_v11();
        let t8 = model_network(&net, 8, UsbLink::usb3_frontpanel()).compute_seconds();
        let t16 = model_network(&net, 16, UsbLink::usb3_frontpanel()).compute_seconds();
        let t32 = model_network(&net, 32, UsbLink::usb3_frontpanel()).compute_seconds();
        assert!(t16 < t8 && t32 < t16);
        // Not perfectly linear (fsum chain grows with p), but substantial.
        assert!(t8 / t16 > 1.2, "{}", t8 / t16);
    }

    #[test]
    fn pcie_cuts_transfer_time() {
        // §6.1: "If USB3.0 can be replaced by PCIe buses, the latency will
        // be improved."
        let net = squeezenet_v11();
        let usb = model_network(&net, 8, UsbLink::usb3_frontpanel());
        let pcie = model_network(&net, 8, UsbLink::pcie_gen2_x4());
        assert!(pcie.transfer_seconds() < usb.transfer_seconds() / 5.0);
        assert_eq!(usb.engine_cycles(), pcie.engine_cycles());
    }

    #[test]
    fn traffic_matches_table2_weight_totals() {
        // Weight bytes of conv1 = 4 × (Table 2 total 4672) per super-block
        // sweep; conv1 fits in one super-block.
        let spec = LayerSpec::conv("conv1", 3, 2, 0, 227, 3, 64, 0);
        let (bytes_in, _, _) = layer_traffic(&spec, 8);
        let weight_bytes = 4 * 4672;
        assert!(bytes_in > weight_bytes);
        // Data bytes: one row slice (5448 values, Table 2 germ) × 113 rows.
        let data_bytes = 113 * 4 * 5448;
        assert_eq!(bytes_in, weight_bytes + data_bytes);
    }
}
